//! Dense tensor substrate for the reference executor.
//!
//! QONNX's convention is that quantized values travel in float containers, so
//! the executor is float-first: `Tensor` is a dense row-major f32 tensor with
//! an i64 variant for shape-carrying tensors (`Shape`, `Gather`, `Reshape`
//! targets). Broadcasting follows numpy/ONNX semantics.
//!
//! Since PR 5 the storage is **dtype-aware**: [`TensorData`] additionally
//! carries `i8` and `i32` payloads so the compiled plan's quantized tier can
//! keep activations *resident* in narrow integer containers between layers
//! (a streamlined `MultiThreshold` emits its integer levels straight into an
//! `i8`/`i32` buffer and the next integer GEMM consumes them without any
//! float detour). The physical container is [`DType`] — distinct from the
//! *logical* arbitrary-precision [`crate::datatypes::DataType`] annotation
//! (`INT3` values live in an `I8` container, `INT17` in `I32`, and an
//! un-streamlined graph keeps everything in `F32` exactly as before).

mod broadcast;
mod gemm;
mod im2col;
mod layout;
mod qgemm;
pub mod simd;
mod store;

pub use broadcast::{broadcast_shapes, broadcastable_to, BroadcastIter};
pub use gemm::{gemm, gemm_prepacked, PackedB, GEMM_KC, GEMM_MC, GEMM_NC};
pub use im2col::{conv_out_dim, im2col_group_into, im2col_nchw};
pub use layout::{nchw_to_nhwc, nhwc_to_nchw};
pub use qgemm::{qgemm_prepacked, qgemm_prepacked_i8, PackedBi8};
pub use simd::Isa;
pub use store::{AlignedBytes, PanelElem, WeightStore, WEIGHT_ALIGN};

use anyhow::{bail, ensure, Result};

/// Largest magnitude exactly representable on the f32 integer grid
/// (`2^24`). The single exactness bound shared by the quantized kernel
/// tier's accumulator proofs ([`crate::plan`]) and streamlining's
/// integer-grid admission checks ([`crate::streamline`]): integers below
/// it round-trip through an f32 container bit-exactly.
pub const F32_EXACT_INT_LIMIT: f64 = 16_777_216.0;

/// Physical element container of a [`Tensor`] (and of a compiled-plan
/// slot). This is storage, not semantics: the *logical* quantized type
/// (`INT3`, `UINT2`, ...) is the [`crate::datatypes::DataType`]
/// annotation; `DType` says which Rust vector holds the values.
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord)]
pub enum DType {
    F32,
    I8,
    I32,
    I64,
}

impl DType {
    /// Bytes per element in this container.
    pub fn size_bytes(self) -> usize {
        match self {
            DType::I8 => 1,
            DType::F32 | DType::I32 => 4,
            DType::I64 => 8,
        }
    }

    /// Short lowercase name (`f32`, `i8`, ...).
    pub fn name(self) -> &'static str {
        match self {
            DType::F32 => "f32",
            DType::I8 => "i8",
            DType::I32 => "i32",
            DType::I64 => "i64",
        }
    }

    /// Inverse of [`DType::name`] (artifact deserialization).
    pub fn from_name(s: &str) -> Option<DType> {
        Some(match s {
            "f32" => DType::F32,
            "i8" => DType::I8,
            "i32" => DType::I32,
            "i64" => DType::I64,
            _ => return None,
        })
    }
}

impl std::fmt::Display for DType {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(self.name())
    }
}

/// Element storage: f32 for data tensors, i64 for shape/index tensors,
/// i8/i32 for integer-resident quantized activations (plan residency).
#[derive(Debug, Clone, PartialEq)]
pub enum TensorData {
    F32(Vec<f32>),
    I8(Vec<i8>),
    I32(Vec<i32>),
    I64(Vec<i64>),
}

/// Dense row-major tensor.
#[derive(Debug, Clone, PartialEq)]
pub struct Tensor {
    shape: Vec<usize>,
    data: TensorData,
}

impl Tensor {
    /// New f32 tensor; panics if `data.len() != product(shape)`.
    pub fn new(shape: Vec<usize>, data: Vec<f32>) -> Tensor {
        assert_eq!(
            shape.iter().product::<usize>(),
            data.len(),
            "shape {shape:?} does not match data length {}",
            data.len()
        );
        Tensor { shape, data: TensorData::F32(data) }
    }

    /// New i64 tensor (shape/index payloads).
    pub fn new_i64(shape: Vec<usize>, data: Vec<i64>) -> Tensor {
        assert_eq!(shape.iter().product::<usize>(), data.len());
        Tensor { shape, data: TensorData::I64(data) }
    }

    /// New i8 tensor (integer-resident quantized activations).
    pub fn new_i8(shape: Vec<usize>, data: Vec<i8>) -> Tensor {
        assert_eq!(shape.iter().product::<usize>(), data.len());
        Tensor { shape, data: TensorData::I8(data) }
    }

    /// New i32 tensor (integer-resident accumulator-domain values).
    pub fn new_i32(shape: Vec<usize>, data: Vec<i32>) -> Tensor {
        assert_eq!(shape.iter().product::<usize>(), data.len());
        Tensor { shape, data: TensorData::I32(data) }
    }

    /// Scalar (rank-0) f32 tensor.
    pub fn scalar(v: f32) -> Tensor {
        Tensor::new(vec![], vec![v])
    }

    /// All-zero f32 tensor.
    pub fn zeros(shape: Vec<usize>) -> Tensor {
        let n = shape.iter().product();
        Tensor::new(shape, vec![0.0; n])
    }

    /// Constant-filled f32 tensor.
    pub fn full(shape: Vec<usize>, v: f32) -> Tensor {
        let n = shape.iter().product();
        Tensor::new(shape, vec![v; n])
    }

    pub fn shape(&self) -> &[usize] {
        &self.shape
    }

    pub fn rank(&self) -> usize {
        self.shape.len()
    }

    pub fn numel(&self) -> usize {
        self.shape.iter().product()
    }

    pub fn is_i64(&self) -> bool {
        matches!(self.data, TensorData::I64(_))
    }

    /// Physical element container of this tensor.
    pub fn dtype(&self) -> DType {
        match self.data {
            TensorData::F32(_) => DType::F32,
            TensorData::I8(_) => DType::I8,
            TensorData::I32(_) => DType::I32,
            TensorData::I64(_) => DType::I64,
        }
    }

    /// Borrow f32 payload; errors on non-f32 containers.
    pub fn as_f32(&self) -> Result<&[f32]> {
        match &self.data {
            TensorData::F32(v) => Ok(v),
            _ => bail!("expected f32 tensor, found {}", self.dtype()),
        }
    }

    /// Mutable f32 payload.
    pub fn as_f32_mut(&mut self) -> Result<&mut [f32]> {
        match &mut self.data {
            TensorData::F32(v) => Ok(v),
            _ => bail!("expected f32 tensor, found {}", self.dtype()),
        }
    }

    /// Borrow i64 payload; errors on other containers.
    pub fn as_i64(&self) -> Result<&[i64]> {
        match &self.data {
            TensorData::I64(v) => Ok(v),
            _ => bail!("expected i64 tensor, found {}", self.dtype()),
        }
    }

    /// Borrow i8 payload; errors on other containers.
    pub fn as_i8(&self) -> Result<&[i8]> {
        match &self.data {
            TensorData::I8(v) => Ok(v),
            _ => bail!("expected i8 tensor, found {}", self.dtype()),
        }
    }

    /// Borrow i32 payload; errors on other containers.
    pub fn as_i32(&self) -> Result<&[i32]> {
        match &self.data {
            TensorData::I32(v) => Ok(v),
            _ => bail!("expected i32 tensor, found {}", self.dtype()),
        }
    }

    /// Payload as i64 values regardless of storage (f32 values are cast;
    /// used where ONNX accepts either int or float inputs, e.g. bit_width).
    pub fn to_i64_vec(&self) -> Vec<i64> {
        match &self.data {
            TensorData::I64(v) => v.clone(),
            TensorData::F32(v) => v.iter().map(|&x| x as i64).collect(),
            TensorData::I8(v) => v.iter().map(|&x| i64::from(x)).collect(),
            TensorData::I32(v) => v.iter().map(|&x| i64::from(x)).collect(),
        }
    }

    /// Take ownership of the f32 payload (buffer recycling: the plan
    /// executor returns released intermediates' storage to its
    /// [`crate::plan::ScratchArena`]). `None` for non-f32 tensors.
    pub fn into_f32_vec(self) -> Option<Vec<f32>> {
        match self.data {
            TensorData::F32(v) => Some(v),
            _ => None,
        }
    }

    /// Take ownership of the raw storage (typed buffer recycling: the
    /// plan executor routes each released intermediate's storage back to
    /// the matching [`crate::plan::ScratchArena`] pool by dtype).
    pub fn into_data(self) -> TensorData {
        self.data
    }

    /// Payload as f64 values regardless of storage.
    pub fn to_f64_vec(&self) -> Vec<f64> {
        match &self.data {
            TensorData::I64(v) => v.iter().map(|&x| x as f64).collect(),
            TensorData::F32(v) => v.iter().map(|&x| f64::from(x)).collect(),
            TensorData::I8(v) => v.iter().map(|&x| f64::from(x)).collect(),
            TensorData::I32(v) => v.iter().map(|&x| f64::from(x)).collect(),
        }
    }

    /// Single-element extraction (rank-0 or single-element tensors).
    pub fn scalar_value(&self) -> Result<f32> {
        ensure!(self.numel() == 1, "expected scalar, shape {:?}", self.shape);
        Ok(match &self.data {
            TensorData::F32(v) => v[0],
            TensorData::I64(v) => v[0] as f32,
            TensorData::I8(v) => f32::from(v[0]),
            TensorData::I32(v) => v[0] as f32,
        })
    }

    /// Reshape preserving element count.
    pub fn reshape(&self, shape: Vec<usize>) -> Result<Tensor> {
        ensure!(
            shape.iter().product::<usize>() == self.numel(),
            "cannot reshape {:?} ({} elems) to {:?}",
            self.shape,
            self.numel(),
            shape
        );
        let mut t = self.clone();
        t.shape = shape;
        Ok(t)
    }

    /// Row-major strides for this shape.
    pub fn strides(&self) -> Vec<usize> {
        strides_for(&self.shape)
    }

    /// Flat offset of a multi-index.
    pub fn offset(&self, idx: &[usize]) -> usize {
        debug_assert_eq!(idx.len(), self.shape.len());
        idx.iter().zip(self.strides()).map(|(i, s)| i * s).sum()
    }

    /// General permutation transpose.
    pub fn transpose(&self, perm: &[usize]) -> Result<Tensor> {
        ensure!(perm.len() == self.rank(), "perm rank mismatch");
        let mut seen = vec![false; perm.len()];
        for &p in perm {
            ensure!(p < perm.len() && !seen[p], "invalid perm {perm:?}");
            seen[p] = true;
        }
        let src = self.as_f32()?;
        let in_strides = self.strides();
        let out_shape: Vec<usize> = perm.iter().map(|&p| self.shape[p]).collect();
        let n = self.numel();
        let mut out = vec![0f32; n];
        let out_strides = strides_for(&out_shape);
        let rank = self.rank();
        let mut idx = vec![0usize; rank];
        for (flat, slot) in out.iter_mut().enumerate() {
            // decompose flat into out multi-index
            let mut rem = flat;
            for d in 0..rank {
                idx[d] = rem / out_strides[d];
                rem %= out_strides[d];
            }
            // out index d corresponds to in index perm[d]
            let mut src_off = 0;
            for d in 0..rank {
                src_off += idx[d] * in_strides[perm[d]];
            }
            *slot = src[src_off];
        }
        Ok(Tensor::new(out_shape, out))
    }

    /// Elementwise binary op with numpy broadcasting. Same-shape and
    /// scalar-rhs cases take direct loops (§Perf: the broadcast iterator
    /// costs ~6x on the elementwise hot path).
    pub fn binary_op(&self, other: &Tensor, f: impl Fn(f32, f32) -> f32) -> Result<Tensor> {
        let a = self.as_f32()?;
        let b = other.as_f32()?;
        if self.shape == other.shape {
            let out: Vec<f32> = a.iter().zip(b).map(|(&x, &y)| f(x, y)).collect();
            return Ok(Tensor::new(self.shape.clone(), out));
        }
        if other.numel() == 1 && self.rank() >= other.rank() {
            let y = b[0];
            let out: Vec<f32> = a.iter().map(|&x| f(x, y)).collect();
            return Ok(Tensor::new(self.shape.clone(), out));
        }
        if self.numel() == 1 && other.rank() >= self.rank() {
            let x = a[0];
            let out: Vec<f32> = b.iter().map(|&y| f(x, y)).collect();
            return Ok(Tensor::new(other.shape.clone(), out));
        }
        let out_shape = broadcast_shapes(&self.shape, &other.shape)?;
        let n: usize = out_shape.iter().product();
        let mut out = Vec::with_capacity(n);
        let ia = BroadcastIter::new(&self.shape, &out_shape);
        let ib = BroadcastIter::new(&other.shape, &out_shape);
        for (oa, ob) in ia.zip(ib) {
            out.push(f(a[oa], b[ob]));
        }
        Ok(Tensor::new(out_shape, out))
    }

    /// Elementwise unary map.
    pub fn map(&self, f: impl Fn(f32) -> f32) -> Result<Tensor> {
        let a = self.as_f32()?;
        Ok(Tensor::new(self.shape.clone(), a.iter().map(|&x| f(x)).collect()))
    }

    /// 2-D matrix multiply: `[m,k] x [k,n] -> [m,n]`. Blocked for cache
    /// friendliness; accumulates in f32 (wide-accumulator checks are done at
    /// the datatype-inference level, not storage level).
    pub fn matmul2d(&self, other: &Tensor) -> Result<Tensor> {
        ensure!(self.rank() == 2 && other.rank() == 2, "matmul2d wants rank-2");
        let (m, k) = (self.shape[0], self.shape[1]);
        let (k2, n) = (other.shape[0], other.shape[1]);
        ensure!(k == k2, "matmul2d inner dim mismatch {k} vs {k2}");
        let a = self.as_f32()?;
        let b = other.as_f32()?;
        let mut out = vec![0f32; m * n];
        gemm(m, k, n, a, b, &mut out);
        Ok(Tensor::new(vec![m, n], out))
    }

    /// Max over all elements.
    pub fn max_value(&self) -> Result<f32> {
        Ok(self.as_f32()?.iter().copied().fold(f32::NEG_INFINITY, f32::max))
    }

    /// Min over all elements.
    pub fn min_value(&self) -> Result<f32> {
        Ok(self.as_f32()?.iter().copied().fold(f32::INFINITY, f32::min))
    }
}

/// Row-major strides for a shape.
pub fn strides_for(shape: &[usize]) -> Vec<usize> {
    let mut strides = vec![1usize; shape.len()];
    for d in (0..shape.len().saturating_sub(1)).rev() {
        strides[d] = strides[d + 1] * shape[d + 1];
    }
    strides
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn construct_and_reshape() {
        let t = Tensor::new(vec![2, 3], vec![1., 2., 3., 4., 5., 6.]);
        assert_eq!(t.numel(), 6);
        let r = t.reshape(vec![3, 2]).unwrap();
        assert_eq!(r.shape(), &[3, 2]);
        assert!(t.reshape(vec![4, 2]).is_err());
    }

    #[test]
    fn scalar_roundtrip() {
        let s = Tensor::scalar(3.5);
        assert_eq!(s.rank(), 0);
        assert_eq!(s.scalar_value().unwrap(), 3.5);
    }

    #[test]
    fn transpose_2d() {
        let t = Tensor::new(vec![2, 3], vec![1., 2., 3., 4., 5., 6.]);
        let tt = t.transpose(&[1, 0]).unwrap();
        assert_eq!(tt.shape(), &[3, 2]);
        assert_eq!(tt.as_f32().unwrap(), &[1., 4., 2., 5., 3., 6.]);
    }

    #[test]
    fn transpose_4d_nchw_nhwc() {
        let t = Tensor::new(vec![1, 2, 2, 2], (0..8).map(|x| x as f32).collect());
        let nhwc = t.transpose(&[0, 2, 3, 1]).unwrap();
        assert_eq!(nhwc.shape(), &[1, 2, 2, 2]);
        // element (c=1, h=0, w=1) = 5 lands at (h=0, w=1, c=1)
        assert_eq!(nhwc.as_f32().unwrap()[0 * 4 + 1 * 2 + 1], 5.0);
    }

    #[test]
    fn broadcast_binary() {
        let a = Tensor::new(vec![2, 3], vec![1., 2., 3., 4., 5., 6.]);
        let b = Tensor::new(vec![3], vec![10., 20., 30.]);
        let c = a.binary_op(&b, |x, y| x + y).unwrap();
        assert_eq!(c.as_f32().unwrap(), &[11., 22., 33., 14., 25., 36.]);
        let s = Tensor::scalar(2.0);
        let d = a.binary_op(&s, |x, y| x * y).unwrap();
        assert_eq!(d.as_f32().unwrap(), &[2., 4., 6., 8., 10., 12.]);
    }

    #[test]
    fn broadcast_column() {
        // [2,1] vs [2,3]
        let a = Tensor::new(vec![2, 1], vec![1., 2.]);
        let b = Tensor::new(vec![2, 3], vec![0.; 6]);
        let c = a.binary_op(&b, |x, _| x).unwrap();
        assert_eq!(c.as_f32().unwrap(), &[1., 1., 1., 2., 2., 2.]);
    }

    #[test]
    fn matmul_small() {
        let a = Tensor::new(vec![2, 2], vec![1., 2., 3., 4.]);
        let b = Tensor::new(vec![2, 2], vec![1., 1., 1., 1.]);
        let c = a.matmul2d(&b).unwrap();
        assert_eq!(c.as_f32().unwrap(), &[3., 3., 7., 7.]);
    }

    #[test]
    fn matmul_rect() {
        let a = Tensor::new(vec![1, 3], vec![1., 2., 3.]);
        let b = Tensor::new(vec![3, 2], vec![1., 0., 0., 1., 1., 1.]);
        let c = a.matmul2d(&b).unwrap();
        assert_eq!(c.as_f32().unwrap(), &[4., 5.]);
    }

    #[test]
    fn i64_tensors() {
        let t = Tensor::new_i64(vec![3], vec![1, -1, 256]);
        assert!(t.is_i64());
        assert!(t.as_f32().is_err());
        assert_eq!(t.as_i64().unwrap(), &[1, -1, 256]);
        assert_eq!(t.to_f64_vec(), vec![1.0, -1.0, 256.0]);
    }

    #[test]
    fn integer_container_tensors() {
        let t8 = Tensor::new_i8(vec![2, 2], vec![-128, -1, 0, 127]);
        assert_eq!(t8.dtype(), DType::I8);
        assert_eq!(t8.as_i8().unwrap(), &[-128, -1, 0, 127]);
        assert!(t8.as_f32().is_err());
        assert_eq!(t8.to_i64_vec(), vec![-128, -1, 0, 127]);
        assert_eq!(t8.to_f64_vec(), vec![-128.0, -1.0, 0.0, 127.0]);
        // reshape is container-agnostic
        let r = t8.reshape(vec![4]).unwrap();
        assert_eq!(r.dtype(), DType::I8);
        assert_eq!(r.shape(), &[4]);

        let t32 = Tensor::new_i32(vec![1], vec![70000]);
        assert_eq!(t32.dtype(), DType::I32);
        assert_eq!(t32.as_i32().unwrap(), &[70000]);
        assert_eq!(t32.scalar_value().unwrap(), 70000.0);
        match t32.into_data() {
            TensorData::I32(v) => assert_eq!(v, vec![70000]),
            other => panic!("wrong payload {other:?}"),
        }
        // f32 recycling path ignores integer containers
        assert!(Tensor::new_i8(vec![1], vec![1]).into_f32_vec().is_none());
        assert_eq!(DType::I8.size_bytes(), 1);
        assert_eq!(DType::F32.size_bytes(), 4);
        assert_eq!(format!("{}", DType::I32), "i32");
    }
}

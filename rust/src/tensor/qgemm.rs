//! Integer GEMM substrate for the quantized kernel tier: `i8` weight
//! panels, `i32` accumulation, explicit-SIMD microkernels.
//!
//! This is the execution form the streamline subsystem
//! ([`crate::streamline`]) lowers to: once datatype inference proves that
//! activations and weights live on an INT≤8 grid, the float GEMM's 4-byte
//! weight traffic shrinks to 1 byte per element and the inner loop becomes
//! a pure integer multiply-accumulate (NEMO and the TVM QNN compiler make
//! the same move — an explicit integer stage is what unlocks low-bit
//! speed).
//!
//! Layout mirrors [`super::gemm`]: the constant rhs is packed **once at
//! plan-compile time** into `KC x NC` panels ([`PackedBi8`], same block
//! constants as the f32 kernel). When the host CPU has a vector path
//! ([`crate::tensor::simd::detected_isa`]), packing *additionally* builds
//! the microkernel's native interleaved tile form, so the hot loop reads
//! contiguous vectors; `i8` activations then run the AVX2/NEON i8×i8→i32
//! kernel, with the scalar panel loop as the portable fallback (and the
//! `QONNX_FORCE_SCALAR` run-time override).
//!
//! Large problems fan row × column chunks onto the persistent intra-op
//! pool ([`crate::runtime::pool`]) instead of spawning scoped threads per
//! call; short-row/wide-column shapes (TFC batch-1: `m = 1`) split
//! columns at `NC`-panel granularity so cores no longer idle.
//!
//! Unlike the f32 path there is **no accumulation-order contract**:
//! integer addition is associative, so any blocking/threading/ISA
//! produces the same bits. Callers guarantee no overflow — the plan
//! compiler only selects this tier when the inferred value ranges bound
//! every accumulator below `2^24` (which also keeps the result exactly
//! representable when it is handed back in an f32 container).

use super::gemm::{GEMM_KC, GEMM_MC, GEMM_NC};
use super::simd::{self, Isa, J_GROUP, K_GROUP};
use super::store::WeightStore;
use crate::runtime::pool;

/// Below this many integer MACs the fan-out overhead dominates.
const PAR_MAC_THRESHOLD: usize = 2_000_000;

/// Interleaved-tile companion to the panel form: the same `[k, n]`
/// matrix re-laid for the vector microkernel (see
/// [`crate::tensor::simd`] for the layout), built once at pack time.
#[derive(Debug, Clone, PartialEq)]
struct SimdTiles {
    /// ISA the tiles were packed for (recorded for kernel reports).
    isa: Isa,
    /// Sum of 8-padded column extents over one full-`KC` tile row.
    np_total: usize,
    data: WeightStore<i8>,
}

impl SimdTiles {
    fn build(k: usize, n: usize, b: &[i8], isa: Isa) -> SimdTiles {
        let mut np_total = 0;
        for nc0 in (0..n).step_by(GEMM_NC) {
            np_total += (n - nc0).min(GEMM_NC).div_ceil(J_GROUP) * J_GROUP;
        }
        let mut data = Vec::new();
        for kc0 in (0..k).step_by(GEMM_KC) {
            let kc_len = (k - kc0).min(GEMM_KC);
            for nc0 in (0..n).step_by(GEMM_NC) {
                let nc_len = (n - nc0).min(GEMM_NC);
                simd::interleave_tile(b, n, kc0, kc_len, nc0, nc_len, &mut data);
            }
        }
        SimdTiles { isa, np_total, data: data.into() }
    }

    /// The interleaved tile at block origin `(kc0, nc0)`. `kc0` is a
    /// multiple of `KC`, `nc0` of `NC` (so every preceding tile row has
    /// `kp = KC` and every preceding tile in this row has `np = NC`).
    #[inline]
    fn tile(&self, kc0: usize, kc_len: usize, nc0: usize, nc_len: usize) -> &[i8] {
        let kp = kc_len.div_ceil(K_GROUP) * K_GROUP;
        let np = nc_len.div_ceil(J_GROUP) * J_GROUP;
        let off = kc0 * self.np_total + kp * nc0;
        &self.data[off..off + kp * np]
    }
}

/// A `[k, n]` `i8` matrix packed into contiguous `KC x NC` panels
/// (identical layout to [`super::PackedB`], 1/4 the bytes), plus — when
/// a vector ISA is active at pack time — the microkernel's interleaved
/// tile form.
#[derive(Debug, Clone, PartialEq)]
pub struct PackedBi8 {
    k: usize,
    n: usize,
    data: WeightStore<i8>,
    /// Compile-time sparsity hint: `true` means the inferred activation
    /// grid is dense (> 2 bits), so the scalar path drops its `av == 0`
    /// skip; `false` (1–2 bit grids) keeps it.
    dense: bool,
    simd: Option<SimdTiles>,
}

impl PackedBi8 {
    /// Pack a row-major `[k, n]` matrix with the conservative sparse
    /// hint (keep the zero-skip). A pure reordering copy (plus the
    /// interleaved SIMD form when the host has a vector path).
    pub fn pack(k: usize, n: usize, b: &[i8]) -> PackedBi8 {
        Self::pack_with(k, n, b, false)
    }

    /// [`PackedBi8::pack`] with an explicit activation-density hint from
    /// the plan compiler's range inference: `dense = true` (8-bit-ish
    /// grids) drops the scalar path's `av == 0` skip, which pessimizes
    /// dense w8a8 activations and blocks vectorization; `false` (1–2 bit
    /// grids, where zeros are common) keeps it.
    pub fn pack_with(k: usize, n: usize, b: &[i8], dense: bool) -> PackedBi8 {
        debug_assert_eq!(b.len(), k * n);
        let mut data = Vec::with_capacity(k * n);
        for kc0 in (0..k).step_by(GEMM_KC) {
            let kc1 = (kc0 + GEMM_KC).min(k);
            for nc0 in (0..n).step_by(GEMM_NC) {
                let nc1 = (nc0 + GEMM_NC).min(n);
                for kk in kc0..kc1 {
                    data.extend_from_slice(&b[kk * n + nc0..kk * n + nc1]);
                }
            }
        }
        let simd = match simd::active_isa() {
            Isa::Scalar => None,
            isa => Some(SimdTiles::build(k, n, b, isa)),
        };
        PackedBi8 { k, n, data: data.into(), dense, simd }
    }

    /// Reassemble a matrix from persisted panel bytes (artifact loading):
    /// the exact storage [`PackedBi8::pack_with`] would have produced,
    /// minus the packing work. `simd` carries `(isa, np_total, tiles)`
    /// when the artifact has interleaved tiles for the current ISA.
    pub(crate) fn from_parts(
        k: usize,
        n: usize,
        data: WeightStore<i8>,
        dense: bool,
        simd: Option<(Isa, usize, WeightStore<i8>)>,
    ) -> PackedBi8 {
        assert_eq!(data.len(), k * n, "packed i8 panel length must be k*n");
        let simd = simd.map(|(isa, np_total, data)| SimdTiles { isa, np_total, data });
        PackedBi8 { k, n, data, dense, simd }
    }

    /// The panel-form storage (artifact writing).
    pub(crate) fn store(&self) -> &WeightStore<i8> {
        &self.data
    }

    /// The interleaved-tile companion as `(isa, np_total, tiles)`, when
    /// present (artifact writing; `SimdTiles` itself stays private).
    pub(crate) fn simd_parts(&self) -> Option<(Isa, usize, &WeightStore<i8>)> {
        self.simd.as_ref().map(|t| (t.isa, t.np_total, &t.data))
    }

    pub fn k(&self) -> usize {
        self.k
    }

    pub fn n(&self) -> usize {
        self.n
    }

    /// The vector ISA this matrix carries interleaved tiles for, if any.
    pub fn simd_isa(&self) -> Option<Isa> {
        self.simd.as_ref().map(|t| t.isa)
    }

    /// The compile-time activation-density hint this matrix was packed
    /// with (see [`PackedBi8::pack_with`]).
    pub fn dense_hint(&self) -> bool {
        self.dense
    }

    /// Largest `|weight|` in the packed panels. Packing is a pure
    /// reordering (zero padding only lives in the interleaved SIMD form),
    /// so this equals the max over the original `[k, n]` matrix — the
    /// `w_abs` term of the compile-time accumulator bound, re-derivable
    /// by the plan verifier without the source weights.
    pub fn max_abs(&self) -> i32 {
        self.data.iter().map(|&v| i32::from(v).abs()).max().unwrap_or(0)
    }

    /// The contiguous `kc_len x nc_len` panel tile at block origin
    /// `(kc0, nc0)`.
    #[inline]
    fn tile(&self, kc0: usize, kc_len: usize, nc0: usize) -> &[i8] {
        let off = kc0 * self.n + kc_len * nc0;
        let nc_len = (self.n - nc0).min(GEMM_NC);
        &self.data[off..off + kc_len * nc_len]
    }
}

/// Activation element of the integer GEMM: `i32` (widened levels) or
/// `i8` (resident levels — the type the vector microkernel accepts).
pub(crate) trait QAct: Copy + Into<i32> + Send + Sync {
    /// The activation slice as raw `i8`, when that is its actual type.
    fn as_i8(a: &[Self]) -> Option<&[i8]>;
}

impl QAct for i32 {
    fn as_i8(_: &[i32]) -> Option<&[i8]> {
        None
    }
}

impl QAct for i8 {
    fn as_i8(a: &[i8]) -> Option<&[i8]> {
        Some(a)
    }
}

/// Integer GEMM against a pre-packed `i8` rhs:
/// `out[m,n] += a[m,k] * bp[k,n]`, accumulating in `i32`.
///
/// Large problems fan out over the persistent intra-op pool; each output
/// element is owned by exactly one job. Exact for any order (integer
/// arithmetic), so every path — scalar, SIMD, threaded — produces
/// identical bits.
pub fn qgemm_prepacked(m: usize, k: usize, bp: &PackedBi8, a: &[i32], out: &mut [i32]) {
    qgemm_generic(m, k, bp, a, out);
}

/// [`qgemm_prepacked`] over **`i8` activations** — the resident-activation
/// path: when the previous layer's `MultiThreshold` emitted its levels
/// into an `i8` container, the activation panel read here is 1 byte per
/// element instead of 4, and the explicit vector microkernel
/// ([`crate::tensor::simd`]) engages when the host has one. Bit-identical
/// to widening up front.
pub fn qgemm_prepacked_i8(m: usize, k: usize, bp: &PackedBi8, a: &[i8], out: &mut [i32]) {
    qgemm_generic(m, k, bp, a, out);
}

/// Row × column fan-out for `threads` lanes: rows first, then `NC`-panel
/// column chunks once rows are exhausted (short-row/wide-column shapes —
/// TFC batch-1 has `m = 1` — would otherwise leave cores idle).
pub(crate) fn par_grid(m: usize, n: usize, threads: usize) -> (usize, usize) {
    let rows = threads.min(m).max(1);
    let cols = if rows < threads {
        (threads / rows).min(n.div_ceil(GEMM_NC)).max(1)
    } else {
        1
    };
    (rows, cols)
}

/// Raw output cursor handed to pool jobs. Each job writes a disjoint
/// (row-range × column-range) rectangle, so sharing the base pointer is
/// race-free.
#[derive(Clone, Copy)]
pub(crate) struct SendPtr<T>(pub(crate) *mut T);
// SAFETY: jobs holding a `SendPtr` write disjoint rectangles of one
// output buffer and are joined (pool latch) before the buffer is reused,
// so moving the raw pointer across threads cannot race.
unsafe impl<T> Send for SendPtr<T> {}

fn qgemm_generic<A: QAct>(m: usize, k: usize, bp: &PackedBi8, a: &[A], out: &mut [i32]) {
    debug_assert_eq!(bp.k, k);
    debug_assert_eq!(a.len(), m * k);
    debug_assert_eq!(out.len(), m * bp.n);
    let n = bp.n;
    if m == 0 || n == 0 || k == 0 {
        return;
    }
    // run-time override: tiles packed for a vector ISA still run the
    // scalar panel loop under QONNX_FORCE_SCALAR
    let isa = if simd::force_scalar() { None } else { bp.simd_isa() };
    let macs = m * k * n;
    let threads = pool::effective_parallelism();
    let (row_chunks, col_chunks) = par_grid(m, n, threads);
    let base = SendPtr(out.as_mut_ptr());
    if threads <= 1 || macs < PAR_MAC_THRESHOLD || row_chunks * col_chunks <= 1 {
        // SAFETY: the single "job" covers the whole (rows × cols) rect.
        unsafe { qgemm_block(k, a, bp, isa, 0, m, 0, n, base.0) };
        return;
    }
    let rows_per = m.div_ceil(row_chunks);
    let nc_blocks = n.div_ceil(GEMM_NC);
    let blocks_per = nc_blocks.div_ceil(col_chunks);
    let mut jobs: Vec<Box<dyn FnOnce() + Send + '_>> = Vec::new();
    let mut r0 = 0usize;
    while r0 < m {
        let r1 = (r0 + rows_per).min(m);
        let mut blk = 0usize;
        while blk < nc_blocks {
            let c0 = blk * GEMM_NC;
            let c1 = ((blk + blocks_per) * GEMM_NC).min(n);
            let p = base;
            jobs.push(Box::new(move || {
                // SAFETY: this job exclusively owns rows r0..r1 of
                // columns c0..c1; rectangles of distinct jobs are
                // disjoint and the pool joins before `out` is reused.
                unsafe { qgemm_block(k, a, bp, isa, r0, r1, c0, c1, p.0) }
            }));
            blk += blocks_per;
        }
        r0 = r1;
    }
    pool::global().run_scoped(jobs);
}

/// Blocked kernel over the `(row0..row1) × (col0..col1)` rectangle of the
/// full `[m, n]` output (`col0` is `NC`-panel aligned). Same
/// MC -> KC -> NC -> row nest as the f32 kernel; dispatches each
/// (row, tile) strip to the vector microkernel when `isa` says so, else
/// to the scalar panel loop.
///
/// # Safety
/// `out` must point at the full `[m, n]` output and the caller must own
/// the rectangle exclusively for the duration of the call.
unsafe fn qgemm_block<A: QAct>(
    k: usize,
    a: &[A],
    bp: &PackedBi8,
    isa: Option<Isa>,
    row0: usize,
    row1: usize,
    col0: usize,
    col1: usize,
    out: *mut i32,
) {
    let n = bp.n;
    debug_assert_eq!(col0 % GEMM_NC, 0);
    let vector = match (isa, &bp.simd, A::as_i8(a)) {
        (Some(isa), Some(tiles), Some(a8)) => Some((isa, tiles, a8)),
        _ => None,
    };
    for ic0 in (row0..row1).step_by(GEMM_MC) {
        let ic1 = (ic0 + GEMM_MC).min(row1);
        for kc0 in (0..k).step_by(GEMM_KC) {
            let kc_len = (k - kc0).min(GEMM_KC);
            for nc0 in (col0..col1).step_by(GEMM_NC) {
                let nc_len = (col1 - nc0).min(GEMM_NC);
                if let Some((isa, tiles, a8)) = vector {
                    let tile = tiles.tile(kc0, kc_len, nc0, nc_len);
                    for i in ic0..ic1 {
                        let arow = &a8[i * k + kc0..i * k + kc0 + kc_len];
                        // SAFETY: `out` spans the full `[m, n]` buffer and
                        // this call owns its rectangle exclusively (fn
                        // contract): `nc_len` elements at `i * n + nc0` are
                        // in bounds and unaliased.
                        let orow = unsafe {
                            std::slice::from_raw_parts_mut(out.add(i * n + nc0), nc_len)
                        };
                        simd::tile_dot(isa, arow, tile, orow);
                    }
                } else {
                    let tile = bp.tile(kc0, kc_len, nc0);
                    for i in ic0..ic1 {
                        let arow = &a[i * k + kc0..i * k + kc0 + kc_len];
                        // SAFETY: same rectangle-ownership argument as the
                        // vector path above.
                        let orow = unsafe {
                            std::slice::from_raw_parts_mut(out.add(i * n + nc0), nc_len)
                        };
                        row_tile_scalar(arow, tile, nc_len, bp.dense, orow);
                    }
                }
            }
        }
    }
}

/// One activation strip × one panel tile on the scalar path. The
/// `av == 0` skip only runs when the compile-time hint says the
/// activation grid is sparse (1–2 bits); dense grids take the
/// branch-free loop that autovectorizes.
#[inline]
fn row_tile_scalar<A: QAct>(arow: &[A], tile: &[i8], nc_len: usize, dense: bool, orow: &mut [i32]) {
    if dense {
        for (kk, &av) in arow.iter().enumerate() {
            let av: i32 = av.into();
            let brow = &tile[kk * nc_len..(kk + 1) * nc_len];
            for (o, &bv) in orow.iter_mut().zip(brow) {
                *o += av * i32::from(bv);
            }
        }
    } else {
        for (kk, &av) in arow.iter().enumerate() {
            let av: i32 = av.into();
            if av == 0 {
                continue; // low-bit activations are often sparse
            }
            let brow = &tile[kk * nc_len..(kk + 1) * nc_len];
            for (o, &bv) in orow.iter_mut().zip(brow) {
                *o += av * i32::from(bv);
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn qgemm_naive(m: usize, k: usize, n: usize, a: &[i32], b: &[i8]) -> Vec<i32> {
        let mut out = vec![0i32; m * n];
        for i in 0..m {
            for j in 0..n {
                let mut acc = 0i32;
                for kk in 0..k {
                    acc += a[i * k + kk] * i32::from(b[kk * n + j]);
                }
                out[i * n + j] = acc;
            }
        }
        out
    }

    fn fill_i32(len: usize, seed: u64, span: i32) -> Vec<i32> {
        let mut s = seed.wrapping_mul(0x9E3779B97F4A7C15).wrapping_add(1);
        (0..len)
            .map(|_| {
                s = s.wrapping_mul(6364136223846793005).wrapping_add(1442695040888963407);
                ((s >> 40) as i32).rem_euclid(2 * span + 1) - span
            })
            .collect()
    }

    fn fill_i8(len: usize, seed: u64) -> Vec<i8> {
        fill_i32(len, seed, 127).into_iter().map(|v| v as i8).collect()
    }

    #[test]
    fn prop_blocked_matches_naive_on_odd_shapes() {
        // under miri the multi-million-MAC shapes take hours; keep the
        // small cases plus one crossing each MC/KC/NC block boundary
        // (coverage, not throughput — miri checks UB, not speed)
        let shapes: &[(usize, usize, usize)] = if cfg!(miri) {
            &[
                (1, 1, 1),
                (1, 7, 3),
                (3, 5, 2),
                (13, 130, 17),
                (GEMM_MC + 1, GEMM_KC + 1, 3),
                (1, 7, GEMM_NC + 1),
            ]
        } else {
            &[
                (1, 1, 1),
                (1, 7, 3),
                (3, 5, 2),
                (7, 1000, 3),
                (13, 130, 17),
                (64, 256, 128),
                (65, 257, 129),
                (GEMM_MC + 3, GEMM_KC + 5, GEMM_NC + 7),
            ]
        };
        for &(m, k, n) in shapes {
            let a = fill_i32(m * k, (m * 31 + k) as u64, 255);
            let b = fill_i8(k * n, (k * 17 + n) as u64);
            let want = qgemm_naive(m, k, n, &a, &b);
            let bp = PackedBi8::pack(k, n, &b);
            let mut got = vec![0i32; m * n];
            qgemm_prepacked(m, k, &bp, &a, &mut got);
            assert_eq!(got, want, "qgemm diverged at m={m} k={k} n={n}");
        }
    }

    #[test]
    fn prop_i8_simd_path_matches_naive_on_odd_shapes() {
        // exercises the vector microkernel whenever the host has one
        // (pack() builds interleaved tiles for the detected ISA)
        let shapes: &[(usize, usize, usize)] = if cfg!(miri) {
            &[(1, 7, 3), (5, 64, 200), (13, 130, 17)]
        } else {
            &[
                (1, 7, 3),
                (5, 64, 200),
                (13, 130, 17),
                (65, 257, 129),
                (GEMM_MC + 1, GEMM_KC + 3, GEMM_NC + 9),
            ]
        };
        for &(m, k, n) in shapes {
            let a8 = fill_i8(m * k, (m * 13 + n) as u64);
            let a32: Vec<i32> = a8.iter().map(|&v| i32::from(v)).collect();
            let b = fill_i8(k * n, (k * 29 + m) as u64);
            let want = qgemm_naive(m, k, n, &a32, &b);
            let bp = PackedBi8::pack(k, n, &b);
            let mut got = vec![0i32; m * n];
            qgemm_prepacked_i8(m, k, &bp, &a8, &mut got);
            assert_eq!(got, want, "i8 simd path diverged at m={m} k={k} n={n} ({:?})", bp.simd_isa());
        }
    }

    #[test]
    #[cfg_attr(miri, ignore = "million-MAC extremes; the saturation proof runs tile-level in tensor::simd")]
    fn adversarial_extremes_survive_simd_dispatch() {
        // all-(-128) activations × all-(-128) weights and alternating-sign
        // K-pairs, end-to-end through qgemm (the tile-level versions live
        // in tensor::simd) — pins the maddubs saturation fix
        let (m, k, n) = (3usize, 512usize, 160usize);
        let a8 = vec![-128i8; m * k];
        let b = vec![-128i8; k * n];
        let a32: Vec<i32> = a8.iter().map(|&v| i32::from(v)).collect();
        let bp = PackedBi8::pack(k, n, &b);
        let mut got = vec![0i32; m * n];
        qgemm_prepacked_i8(m, k, &bp, &a8, &mut got);
        assert_eq!(got, qgemm_naive(m, k, n, &a32, &b));
        let alt: Vec<i8> = (0..m * k).map(|i| if i % 2 == 0 { 127 } else { -128 }).collect();
        let alt32: Vec<i32> = alt.iter().map(|&v| i32::from(v)).collect();
        let mut got = vec![0i32; m * n];
        qgemm_prepacked_i8(m, k, &bp, &alt, &mut got);
        assert_eq!(got, qgemm_naive(m, k, n, &alt32, &b));
    }

    #[test]
    fn dense_hint_changes_nothing_numerically() {
        let (m, k, n) = if cfg!(miri) { (5usize, 60usize, 20usize) } else { (9, 300, 50) };
        // plenty of zero activations so the skip actually fires
        let a: Vec<i32> = fill_i32(m * k, 5, 2);
        let b = fill_i8(k * n, 6);
        let sparse = PackedBi8::pack_with(k, n, &b, false);
        let dense = PackedBi8::pack_with(k, n, &b, true);
        assert!(!sparse.dense_hint());
        assert!(dense.dense_hint());
        let mut got_s = vec![0i32; m * n];
        let mut got_d = vec![0i32; m * n];
        qgemm_prepacked(m, k, &sparse, &a, &mut got_s);
        qgemm_prepacked(m, k, &dense, &a, &mut got_d);
        assert_eq!(got_s, got_d);
        assert_eq!(got_s, qgemm_naive(m, k, n, &a, &b));
    }

    #[test]
    fn i8_activation_path_matches_i32_path() {
        let shapes: &[(usize, usize, usize)] = if cfg!(miri) {
            &[(1, 7, 3), (13, 130, 17)]
        } else {
            &[(1, 7, 3), (13, 130, 17), (65, 257, 129)]
        };
        for &(m, k, n) in shapes {
            let a8 = fill_i8(m * k, (m * 7 + n) as u64);
            let a32: Vec<i32> = a8.iter().map(|&v| i32::from(v)).collect();
            let b = fill_i8(k * n, (k * 3 + m) as u64);
            let bp = PackedBi8::pack(k, n, &b);
            let mut want = vec![0i32; m * n];
            qgemm_prepacked(m, k, &bp, &a32, &mut want);
            let mut got = vec![0i32; m * n];
            qgemm_prepacked_i8(m, k, &bp, &a8, &mut got);
            assert_eq!(got, want, "i8 activations diverged at m={m} k={k} n={n}");
        }
    }

    #[test]
    #[cfg_attr(miri, ignore = "PAR_MAC_THRESHOLD forces a multi-million-MAC shape; pool handoffs are covered in runtime::pool")]
    fn single_row_wide_output_splits_columns() {
        // m = 1 used to force the serial path no matter how many cores
        // (threads.min(m)); with the pool it splits NC panels instead.
        // Correctness must hold on any machine, whichever path engages.
        let (m, k, n) = (1usize, 2000usize, 1100usize);
        assert!(m * k * n >= PAR_MAC_THRESHOLD);
        let (rows, cols) = par_grid(m, n, 8);
        assert_eq!(rows, 1);
        assert_eq!(cols, 8);
        let a = fill_i32(m * k, 77, 127);
        let b = fill_i8(k * n, 78);
        let bp = PackedBi8::pack(k, n, &b);
        let mut got = vec![0i32; m * n];
        qgemm_prepacked(m, k, &bp, &a, &mut got);
        assert_eq!(got, qgemm_naive(m, k, n, &a, &b));
    }

    #[test]
    fn par_grid_budgets_rows_then_columns() {
        assert_eq!(par_grid(16, 4096, 8), (8, 1));
        assert_eq!(par_grid(2, 4096, 8), (2, 4));
        assert_eq!(par_grid(1, 100, 8), (1, 1)); // single NC block
        assert_eq!(par_grid(1, 4096, 1), (1, 1));
        assert_eq!(par_grid(3, 4096, 8), (3, 2));
    }

    #[test]
    fn degenerate_dims_are_noops() {
        let mut out: Vec<i32> = vec![];
        let bp = PackedBi8::pack(0, 3, &[]);
        assert_eq!(bp.k(), 0);
        assert_eq!(bp.n(), 3);
        qgemm_prepacked(0, 0, &bp, &[], &mut out);
        let bp2 = PackedBi8::pack(0, 2, &[]);
        let mut out2 = vec![0i32; 4];
        qgemm_prepacked(2, 0, &bp2, &[], &mut out2);
        assert_eq!(out2, vec![0; 4]);
    }

    #[test]
    fn pack_roundtrips_values() {
        let (k, n) = (GEMM_KC + 2, GEMM_NC + 5);
        let b = fill_i8(k * n, 9);
        let bp = PackedBi8::pack(k, n, &b);
        let mut a = vec![0i32; k];
        a[3] = 1;
        let mut out = vec![0i32; n];
        qgemm_prepacked(1, k, &bp, &a, &mut out);
        let want: Vec<i32> = b[3 * n..4 * n].iter().map(|&v| i32::from(v)).collect();
        assert_eq!(out, want);
    }
}

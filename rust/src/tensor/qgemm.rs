//! Integer GEMM substrate for the quantized kernel tier: `i8` weight
//! panels, `i32` accumulation.
//!
//! This is the execution form the streamline subsystem
//! ([`crate::streamline`]) lowers to: once datatype inference proves that
//! activations and weights live on an INT≤8 grid, the float GEMM's 4-byte
//! weight traffic shrinks to 1 byte per element and the inner loop becomes
//! a pure integer multiply-accumulate (NEMO and the TVM QNN compiler make
//! the same move — an explicit integer stage is what unlocks low-bit
//! speed).
//!
//! Layout mirrors [`super::gemm`]: the constant rhs is packed **once at
//! plan-compile time** into `KC x NC` panels ([`PackedBi8`], same block
//! constants as the f32 kernel), rows are walked in `MC` blocks and fanned
//! out over threads for large problems.
//!
//! Unlike the f32 path there is **no accumulation-order contract**:
//! integer addition is associative, so any blocking/threading produces the
//! same bits. Callers guarantee no overflow — the plan compiler only
//! selects this tier when the inferred value ranges bound every
//! accumulator below `2^24` (which also keeps the result exactly
//! representable when it is handed back in an f32 container).

use super::gemm::{GEMM_KC, GEMM_MC, GEMM_NC};

/// Below this many integer MACs the thread-spawn overhead dominates.
const PAR_MAC_THRESHOLD: usize = 2_000_000;

/// A `[k, n]` `i8` matrix packed into contiguous `KC x NC` panels
/// (identical layout to [`super::PackedB`], 1/4 the bytes).
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct PackedBi8 {
    k: usize,
    n: usize,
    data: Vec<i8>,
}

impl PackedBi8 {
    /// Pack a row-major `[k, n]` matrix. A pure reordering copy.
    pub fn pack(k: usize, n: usize, b: &[i8]) -> PackedBi8 {
        debug_assert_eq!(b.len(), k * n);
        let mut data = Vec::with_capacity(k * n);
        for kc0 in (0..k).step_by(GEMM_KC) {
            let kc1 = (kc0 + GEMM_KC).min(k);
            for nc0 in (0..n).step_by(GEMM_NC) {
                let nc1 = (nc0 + GEMM_NC).min(n);
                for kk in kc0..kc1 {
                    data.extend_from_slice(&b[kk * n + nc0..kk * n + nc1]);
                }
            }
        }
        PackedBi8 { k, n, data }
    }

    pub fn k(&self) -> usize {
        self.k
    }

    pub fn n(&self) -> usize {
        self.n
    }

    /// The contiguous `kc_len x nc_len` tile at block origin `(kc0, nc0)`.
    #[inline]
    fn tile(&self, kc0: usize, kc_len: usize, nc0: usize) -> &[i8] {
        let off = kc0 * self.n + kc_len * nc0;
        let nc_len = (self.n - nc0).min(GEMM_NC);
        &self.data[off..off + kc_len * nc_len]
    }
}

/// Integer GEMM against a pre-packed `i8` rhs:
/// `out[m,n] += a[m,k] * bp[k,n]`, accumulating in `i32`.
///
/// Threads split the row range for large problems; each output element is
/// owned by exactly one thread. Exact for any order (integer arithmetic).
pub fn qgemm_prepacked(m: usize, k: usize, bp: &PackedBi8, a: &[i32], out: &mut [i32]) {
    qgemm_generic(m, k, bp, a, out);
}

/// [`qgemm_prepacked`] over **`i8` activations** — the resident-activation
/// path: when the previous layer's `MultiThreshold` emitted its levels
/// into an `i8` container, the activation panel read here is 1 byte per
/// element instead of 4 (and the widening to `i32` happens in-register in
/// the inner loop). Bit-identical to widening up front.
pub fn qgemm_prepacked_i8(m: usize, k: usize, bp: &PackedBi8, a: &[i8], out: &mut [i32]) {
    qgemm_generic(m, k, bp, a, out);
}

fn qgemm_generic<A: Copy + Into<i32> + Sync>(
    m: usize,
    k: usize,
    bp: &PackedBi8,
    a: &[A],
    out: &mut [i32],
) {
    debug_assert_eq!(bp.k, k);
    debug_assert_eq!(a.len(), m * k);
    debug_assert_eq!(out.len(), m * bp.n);
    let n = bp.n;
    if m == 0 || n == 0 || k == 0 {
        return;
    }
    let macs = m * k * n;
    let threads = std::thread::available_parallelism().map(|t| t.get()).unwrap_or(1);
    if threads <= 1 || macs < PAR_MAC_THRESHOLD || m < 2 {
        qgemm_packed_rows(k, a, bp, out);
        return;
    }
    let threads = threads.min(m);
    let rows_per = m.div_ceil(threads);
    std::thread::scope(|scope| {
        let mut rest = out;
        let mut row0 = 0usize;
        for _ in 0..threads {
            let rows = rows_per.min(m - row0);
            if rows == 0 {
                break;
            }
            let (chunk, tail) = rest.split_at_mut(rows * n);
            rest = tail;
            let a_chunk = &a[row0 * k..(row0 + rows) * k];
            scope.spawn(move || qgemm_packed_rows(k, a_chunk, bp, chunk));
            row0 += rows;
        }
    });
}

/// Serial blocked kernel over the rows in `out`, reading packed panels.
/// Same MC -> KC -> NC -> row -> strip nest as the f32 kernel; the
/// widening (`i8 -> i32` on the panel strip, and on the activation when it
/// is `i8`-resident) happens inside the inner loop — the strip is
/// contiguous, so the loop autovectorizes.
fn qgemm_packed_rows<A: Copy + Into<i32>>(k: usize, a: &[A], bp: &PackedBi8, out: &mut [i32]) {
    let n = bp.n;
    if n == 0 {
        return;
    }
    let m = out.len() / n;
    for ic0 in (0..m).step_by(GEMM_MC) {
        let ic1 = (ic0 + GEMM_MC).min(m);
        for kc0 in (0..k).step_by(GEMM_KC) {
            let kc_len = (k - kc0).min(GEMM_KC);
            for nc0 in (0..n).step_by(GEMM_NC) {
                let nc_len = (n - nc0).min(GEMM_NC);
                let tile = bp.tile(kc0, kc_len, nc0);
                for i in ic0..ic1 {
                    let arow = &a[i * k + kc0..i * k + kc0 + kc_len];
                    let orow = &mut out[i * n + nc0..i * n + nc0 + nc_len];
                    for (kk, &av) in arow.iter().enumerate() {
                        let av: i32 = av.into();
                        if av == 0 {
                            continue; // low-bit activations are often sparse
                        }
                        let brow = &tile[kk * nc_len..(kk + 1) * nc_len];
                        for (o, &bv) in orow.iter_mut().zip(brow) {
                            *o += av * i32::from(bv);
                        }
                    }
                }
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn qgemm_naive(m: usize, k: usize, n: usize, a: &[i32], b: &[i8]) -> Vec<i32> {
        let mut out = vec![0i32; m * n];
        for i in 0..m {
            for j in 0..n {
                let mut acc = 0i32;
                for kk in 0..k {
                    acc += a[i * k + kk] * i32::from(b[kk * n + j]);
                }
                out[i * n + j] = acc;
            }
        }
        out
    }

    fn fill_i32(len: usize, seed: u64, span: i32) -> Vec<i32> {
        let mut s = seed.wrapping_mul(0x9E3779B97F4A7C15).wrapping_add(1);
        (0..len)
            .map(|_| {
                s = s.wrapping_mul(6364136223846793005).wrapping_add(1442695040888963407);
                ((s >> 40) as i32).rem_euclid(2 * span + 1) - span
            })
            .collect()
    }

    fn fill_i8(len: usize, seed: u64) -> Vec<i8> {
        fill_i32(len, seed, 127).into_iter().map(|v| v as i8).collect()
    }

    #[test]
    fn prop_blocked_matches_naive_on_odd_shapes() {
        let shapes = [
            (1usize, 1usize, 1usize),
            (1, 7, 3),
            (3, 5, 2),
            (7, 1000, 3),
            (13, 130, 17),
            (64, 256, 128),
            (65, 257, 129),
            (GEMM_MC + 3, GEMM_KC + 5, GEMM_NC + 7),
        ];
        for &(m, k, n) in &shapes {
            let a = fill_i32(m * k, (m * 31 + k) as u64, 255);
            let b = fill_i8(k * n, (k * 17 + n) as u64);
            let want = qgemm_naive(m, k, n, &a, &b);
            let bp = PackedBi8::pack(k, n, &b);
            let mut got = vec![0i32; m * n];
            qgemm_prepacked(m, k, &bp, &a, &mut got);
            assert_eq!(got, want, "qgemm diverged at m={m} k={k} n={n}");
        }
    }

    #[test]
    fn i8_activation_path_matches_i32_path() {
        for &(m, k, n) in &[(1usize, 7usize, 3usize), (13, 130, 17), (65, 257, 129)] {
            let a8 = fill_i8(m * k, (m * 7 + n) as u64);
            let a32: Vec<i32> = a8.iter().map(|&v| i32::from(v)).collect();
            let b = fill_i8(k * n, (k * 3 + m) as u64);
            let bp = PackedBi8::pack(k, n, &b);
            let mut want = vec![0i32; m * n];
            qgemm_prepacked(m, k, &bp, &a32, &mut want);
            let mut got = vec![0i32; m * n];
            qgemm_prepacked_i8(m, k, &bp, &a8, &mut got);
            assert_eq!(got, want, "i8 activations diverged at m={m} k={k} n={n}");
        }
    }

    #[test]
    fn degenerate_dims_are_noops() {
        let mut out: Vec<i32> = vec![];
        let bp = PackedBi8::pack(0, 3, &[]);
        assert_eq!(bp.k(), 0);
        assert_eq!(bp.n(), 3);
        qgemm_prepacked(0, 0, &bp, &[], &mut out);
        let bp2 = PackedBi8::pack(0, 2, &[]);
        let mut out2 = vec![0i32; 4];
        qgemm_prepacked(2, 0, &bp2, &[], &mut out2);
        assert_eq!(out2, vec![0; 4]);
    }

    #[test]
    fn pack_roundtrips_values() {
        let (k, n) = (GEMM_KC + 2, GEMM_NC + 5);
        let b = fill_i8(k * n, 9);
        let bp = PackedBi8::pack(k, n, &b);
        let mut a = vec![0i32; k];
        a[3] = 1;
        let mut out = vec![0i32; n];
        qgemm_prepacked(1, k, &bp, &a, &mut out);
        let want: Vec<i32> = b[3 * n..4 * n].iter().map(|&v| i32::from(v)).collect();
        assert_eq!(out, want);
    }
}

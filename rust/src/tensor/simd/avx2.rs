//! AVX2 i8×i8→i32 tile kernel: `maddubs` pairwise widening with
//! **sign-split activations** (see the module docs for the saturation
//! proof — `maddubs`' i16 pair-sum saturates for full-range u8×i8, and
//! splitting `a = a⁺ − a⁻` bounds both halves inside i16 exactly).

use super::{J_GROUP, K_GROUP};
use crate::tensor::GEMM_KC;

#[cfg(target_arch = "x86_64")]
use std::arch::x86_64::*;

/// # Safety
/// Caller must have verified AVX2 support (`is_x86_feature_detected!`).
/// `tile` must be the interleaved form of a `a.len() × out.len()` tile
/// ([`super::interleave_tile`]), and `a.len() <= GEMM_KC`.
#[target_feature(enable = "avx2")]
pub(super) unsafe fn tile_dot(a: &[i8], tile: &[i8], out: &mut [i32]) {
    let kc = a.len();
    debug_assert!(kc <= GEMM_KC, "activation strip exceeds KC");
    let kp = kc.div_ceil(K_GROUP) * K_GROUP;
    let groups = kp / K_GROUP;
    let nc = out.len();
    let np = nc.div_ceil(J_GROUP) * J_GROUP;
    debug_assert_eq!(tile.len(), kp * np);
    // Sign-split the strip once per (row, tile): a⁺ ∈ [0,127],
    // a⁻ ∈ [0,128], zero-padded to the K_GROUP boundary.
    let mut ap = [0u8; GEMM_KC];
    let mut an = [0u8; GEMM_KC];
    for (i, &v) in a.iter().enumerate() {
        let v = i32::from(v);
        ap[i] = v.max(0) as u8;
        an[i] = (-v).max(0) as u8;
    }
    // SAFETY: AVX2 is available (caller contract, enforced by the
    // `#[target_feature]` gate). The unaligned loads stay in bounds: for
    // each group `base + g*32 + 32 <= (j0/J_GROUP)*kp*J_GROUP + kp*J_GROUP
    // <= kp*np == tile.len()` (asserted above). The store targets a local
    // `[i32; J_GROUP]`, exactly one register wide.
    unsafe {
        let ones = _mm256_set1_epi16(1);
        for j0 in (0..np).step_by(J_GROUP) {
            let base = (j0 / J_GROUP) * kp * J_GROUP;
            let mut acc_p = _mm256_setzero_si256();
            let mut acc_n = _mm256_setzero_si256();
            for g in 0..groups {
                // one chunk = eight 4-byte column groups = one register
                let bv = _mm256_loadu_si256(tile.as_ptr().add(base + g * 32) as *const __m256i);
                let pa = _mm256_set1_epi32(i32::from_le_bytes([
                    ap[K_GROUP * g],
                    ap[K_GROUP * g + 1],
                    ap[K_GROUP * g + 2],
                    ap[K_GROUP * g + 3],
                ]));
                let na = _mm256_set1_epi32(i32::from_le_bytes([
                    an[K_GROUP * g],
                    an[K_GROUP * g + 1],
                    an[K_GROUP * g + 2],
                    an[K_GROUP * g + 3],
                ]));
                // maddubs: saturation-free by the sign-split bound;
                // madd(·, 1): exact pairwise i16→i32 widen
                let p = _mm256_madd_epi16(_mm256_maddubs_epi16(pa, bv), ones);
                let n = _mm256_madd_epi16(_mm256_maddubs_epi16(na, bv), ones);
                acc_p = _mm256_add_epi32(acc_p, p);
                acc_n = _mm256_add_epi32(acc_n, n);
            }
            let acc = _mm256_sub_epi32(acc_p, acc_n);
            let mut lanes = [0i32; J_GROUP];
            _mm256_storeu_si256(lanes.as_mut_ptr() as *mut __m256i, acc);
            // column tail: only write back the valid lanes
            for (jj, &lane) in lanes.iter().take((nc - j0).min(J_GROUP)).enumerate() {
                out[j0 + jj] += lane;
            }
        }
    }
}

//! Explicit-SIMD i8×i8→i32 microkernels behind runtime CPU detection.
//!
//! The quantized tier's inner loop (`qgemm_packed_rows`) is a scalar
//! widen-multiply-accumulate that leans on autovectorization. This module
//! supplies the explicit vector form — the core trick of the TVM QNN
//! compiler and of FINN-R's compute cores: an i8×i8 multiply with
//! pairwise widening into i32 lanes, fed from weight tiles repacked into
//! the kernel's native interleaved layout at plan-compile time.
//!
//! # Dispatch
//!
//! [`detected_isa`] probes the CPU once (`is_x86_feature_detected!` /
//! `is_aarch64_feature_detected!`, cached in a `OnceLock`); the
//! `QONNX_FORCE_SCALAR` env knob overrides it per *call* via
//! [`active_isa`], so a prepacked plan can be flipped to the scalar
//! fallback at run time for A/B checks. Three paths:
//!
//! * **AVX2** — `_mm256_maddubs_epi16` pairwise u8×i8 widening. The
//!   instruction's *saturating* i16 pair-sum is a correctness hazard for
//!   full-range inputs (e.g. zero-offsetting activations to `[0,255]`
//!   still saturates: `255·(−128)·2 = −65280 < i16::MIN`). We use the
//!   **sign-split** fix instead: `a = a⁺ − a⁻` with `a⁺ = max(a,0) ∈
//!   [0,127]` and `a⁻ = max(−a,0) ∈ [0,128]`. Then every maddubs pair-sum
//!   is bounded by `2·127·128 = 32512` on the positive half and by
//!   `−2·128·128 = −32768 = i16::MIN` (exactly representable, so the
//!   saturating add is lossless) on the negative half — saturation-free
//!   even at the `±127`/`−128` extremes. A proof test below pins this.
//! * **NEON** — `vmull_s8` (signed widening multiply, no saturation
//!   hazard) + `vpadalq_s16` pairwise accumulate into i32 lanes.
//! * **Scalar** — the portable fallback. The packed-panel loop behind
//!   [`crate::tensor::qgemm_prepacked`] *is* the scalar path for
//!   production GEMMs; the interleaved-layout scalar walker here
//!   (`tile_dot_scalar`) is the reference the vector paths are tested
//!   against and the safety net on architectures without a kernel.
//!
//! All paths accumulate in exact i32 arithmetic, so they produce
//! **identical bits** — the plan compiler's `< 2^24` accumulator proof
//! makes overflow impossible and integer addition is order-free.
//!
//! # Interleaved tile layout
//!
//! Weight tiles (`KC×NC` blocks, same constants as the f32 kernel) are
//! repacked once at plan-compile time into the microkernel's native
//! layout: the k-extent padded to a multiple of [`K_GROUP`] (4) and the
//! column extent to a multiple of [`J_GROUP`] (8) with zeros, then laid
//! out j8-block-major:
//!
//! ```text
//! for each 8-column block j0:
//!   for each 4-row group k0:
//!     32 bytes: [b(k0..k0+4, j0), b(k0..k0+4, j0+1), … b(k0..k0+4, j0+7)]
//! ```
//!
//! One 32-byte chunk is exactly one AVX2 register (eight 4-byte column
//! groups) and two NEON registers, so the hot loop reads contiguous,
//! aligned-stride vectors with no gather. Zero padding contributes 0 to
//! every dot product; column-tail lanes are masked on write-back.

#[cfg(target_arch = "x86_64")]
mod avx2;
#[cfg(target_arch = "aarch64")]
mod neon;

use std::sync::OnceLock;

/// k-extent grouping of the interleaved layout (bytes per column group).
pub const K_GROUP: usize = 4;
/// Column grouping of the interleaved layout (one 32-byte chunk).
pub const J_GROUP: usize = 8;

/// Instruction set the i8 microkernel dispatches to.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Isa {
    /// Portable scalar loop (also the `QONNX_FORCE_SCALAR` target).
    Scalar,
    /// x86-64 AVX2 `maddubs` path (sign-split activations).
    Avx2,
    /// AArch64 NEON `vmull_s8`/`vpadalq_s16` path.
    Neon,
}

impl Isa {
    /// Short lowercase name for reports (`plan` summary, `serve` banner).
    pub fn name(self) -> &'static str {
        match self {
            Isa::Scalar => "scalar",
            Isa::Avx2 => "avx2",
            Isa::Neon => "neon",
        }
    }

    /// Whether this is a vector path (i.e. interleaved tiles are built).
    pub fn is_simd(self) -> bool {
        self != Isa::Scalar
    }

    /// Inverse of [`Isa::name`] — used by the artifact loader to compare
    /// the ISA recorded at pack time against the current host.
    pub fn from_name(s: &str) -> Option<Isa> {
        match s {
            "scalar" => Some(Isa::Scalar),
            "avx2" => Some(Isa::Avx2),
            "neon" => Some(Isa::Neon),
            _ => None,
        }
    }
}

impl std::fmt::Display for Isa {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(self.name())
    }
}

fn detect() -> Isa {
    #[cfg(target_arch = "x86_64")]
    {
        if std::arch::is_x86_feature_detected!("avx2") {
            return Isa::Avx2;
        }
    }
    #[cfg(target_arch = "aarch64")]
    {
        if std::arch::is_aarch64_feature_detected!("neon") {
            return Isa::Neon;
        }
    }
    Isa::Scalar
}

/// The best ISA the CPU supports, probed once per process.
pub fn detected_isa() -> Isa {
    static DETECTED: OnceLock<Isa> = OnceLock::new();
    *DETECTED.get_or_init(detect)
}

/// Whether `QONNX_FORCE_SCALAR` demands the portable fallback. Read per
/// call (not cached) so tests and operators can flip it at run time.
pub fn force_scalar() -> bool {
    std::env::var_os("QONNX_FORCE_SCALAR").is_some_and(|v| !v.is_empty() && v != "0")
}

/// The ISA in effect right now: [`detected_isa`] unless
/// `QONNX_FORCE_SCALAR` overrides it.
pub fn active_isa() -> Isa {
    if force_scalar() {
        Isa::Scalar
    } else {
        detected_isa()
    }
}

/// Padded byte length of one interleaved `kc_len × nc_len` tile.
pub(crate) fn padded_tile_len(kc_len: usize, nc_len: usize) -> usize {
    kc_len.div_ceil(K_GROUP) * K_GROUP * nc_len.div_ceil(J_GROUP) * J_GROUP
}

/// Append the interleaved form of the `kc_len × nc_len` tile of row-major
/// `b` (`[k, n]`) at block origin `(kc0, nc0)` onto `out`. Out-of-range
/// positions (k/column padding) are zero-filled.
pub(crate) fn interleave_tile(
    b: &[i8],
    n: usize,
    kc0: usize,
    kc_len: usize,
    nc0: usize,
    nc_len: usize,
    out: &mut Vec<i8>,
) {
    let kp = kc_len.div_ceil(K_GROUP) * K_GROUP;
    let np = nc_len.div_ceil(J_GROUP) * J_GROUP;
    out.reserve(kp * np);
    for j0 in (0..np).step_by(J_GROUP) {
        for k0 in (0..kp).step_by(K_GROUP) {
            for jj in 0..J_GROUP {
                for kk in 0..K_GROUP {
                    let (ki, ji) = (k0 + kk, j0 + jj);
                    let v = if ki < kc_len && ji < nc_len {
                        b[(kc0 + ki) * n + (nc0 + ji)]
                    } else {
                        0
                    };
                    out.push(v);
                }
            }
        }
    }
}

/// `out[j] += dot(a, tile_column_j)` over one interleaved tile.
///
/// `a` is the activation strip (`kc_len` values, `kc_len ≤ GEMM_KC`),
/// `tile` the interleaved tile bytes (length
/// `padded_tile_len(a.len(), out.len())`), `out` the `nc_len` output
/// accumulators. Every path produces identical bits (exact i32 math).
#[inline]
pub(crate) fn tile_dot(isa: Isa, a: &[i8], tile: &[i8], out: &mut [i32]) {
    debug_assert_eq!(tile.len(), padded_tile_len(a.len(), out.len()));
    match isa {
        #[cfg(target_arch = "x86_64")]
        // SAFETY: callers only pass Isa::Avx2 when detection proved AVX2.
        Isa::Avx2 => unsafe { avx2::tile_dot(a, tile, out) },
        #[cfg(target_arch = "aarch64")]
        // SAFETY: callers only pass Isa::Neon when detection proved NEON.
        Isa::Neon => unsafe { neon::tile_dot(a, tile, out) },
        _ => tile_dot_scalar(a, tile, out),
    }
}

/// Scalar walker of the interleaved layout — the reference the vector
/// paths are property-tested against, and the fallback when a plan
/// packed tiles for an ISA the run-time override disabled.
pub(crate) fn tile_dot_scalar(a: &[i8], tile: &[i8], out: &mut [i32]) {
    let kc = a.len();
    let kp = kc.div_ceil(K_GROUP) * K_GROUP;
    for (j, o) in out.iter_mut().enumerate() {
        let base = (j / J_GROUP) * kp * J_GROUP + (j % J_GROUP) * K_GROUP;
        let mut acc = 0i32;
        for g in 0..kp / K_GROUP {
            let chunk = base + g * K_GROUP * J_GROUP;
            for kk in 0..K_GROUP {
                let ki = g * K_GROUP + kk;
                if ki < kc {
                    acc += i32::from(a[ki]) * i32::from(tile[chunk + kk]);
                }
            }
        }
        *o += acc;
    }
}

/// Every ISA the current host can actually execute (scalar always).
#[cfg(test)]
pub(crate) fn available_isas() -> Vec<Isa> {
    let mut isas = vec![Isa::Scalar];
    if detected_isa().is_simd() {
        isas.push(detected_isa());
    }
    isas
}

#[cfg(test)]
mod tests {
    use super::*;

    fn naive_dot(a: &[i8], b: &[i8], nc: usize) -> Vec<i32> {
        // b is row-major [a.len(), nc]
        let mut out = vec![0i32; nc];
        for (ki, &av) in a.iter().enumerate() {
            for j in 0..nc {
                out[j] += i32::from(av) * i32::from(b[ki * nc + j]);
            }
        }
        out
    }

    fn check(a: &[i8], b: &[i8], nc: usize) {
        let want = naive_dot(a, b, nc);
        let mut tile = Vec::new();
        interleave_tile(b, nc, 0, a.len(), 0, nc, &mut tile);
        assert_eq!(tile.len(), padded_tile_len(a.len(), nc));
        for isa in available_isas() {
            let mut got = vec![0i32; nc];
            tile_dot(isa, a, &tile, &mut got);
            assert_eq!(got, want, "{isa} diverged at k={} nc={nc}", a.len());
            // accumulation (not overwrite): a second call doubles
            tile_dot(isa, a, &tile, &mut got);
            let doubled: Vec<i32> = want.iter().map(|v| v * 2).collect();
            assert_eq!(got, doubled, "{isa} did not accumulate");
        }
    }

    fn fill_i8(len: usize, seed: u64) -> Vec<i8> {
        let mut s = seed.wrapping_mul(0x9E3779B97F4A7C15).wrapping_add(1);
        (0..len)
            .map(|_| {
                s = s.wrapping_mul(6364136223846793005).wrapping_add(1442695040888963407);
                (s >> 40) as i8
            })
            .collect()
    }

    #[test]
    fn prop_paths_match_naive_on_odd_shapes() {
        for &(k, nc) in &[
            (1usize, 1usize),
            (1, 9),
            (3, 5),
            (4, 8),
            (5, 8),
            (7, 17),
            (31, 63),
            (63, 31),
            (250, 120),
            (255, 127),
            (256, 128),
        ] {
            let a = fill_i8(k, (k * 31 + nc) as u64);
            let b = fill_i8(k * nc, (k * 7 + nc * 3) as u64);
            check(&a, &b, nc);
        }
    }

    #[test]
    fn maddubs_saturation_proof_at_extremes() {
        // The pairs that break a naive maddubs use: every combination of
        // extreme activation and weight values, over a full-depth strip
        // (k = 256 keeps per-pair sums at the ±32512 / −32768 boundary
        // for 64 consecutive groups). A saturating path would clamp and
        // diverge from the exact i32 reference.
        let extremes: [i8; 5] = [-128, -127, 127, 126, 1];
        for &av in &extremes {
            for &bv in &extremes {
                let a = vec![av; 256];
                let b = vec![bv; 256 * 8];
                check(&a, &b, 8);
            }
        }
    }

    #[test]
    fn adversarial_alternating_sign_k_pairs() {
        // Alternating-sign activations make adjacent maddubs pairs land
        // on opposite extremes — the exact shape the sign-split must
        // keep separated (mixing them inside one saturating i16 add is
        // where the zero-offset trick fails).
        let k = 256;
        let a: Vec<i8> = (0..k).map(|i| if i % 2 == 0 { 127 } else { -128 }).collect();
        let b = vec![-128i8; k * 8];
        check(&a, &b, 8);
        let a2: Vec<i8> = (0..k).map(|i| if i % 2 == 0 { -128 } else { 127 }).collect();
        let b2 = vec![127i8; k * 8];
        check(&a2, &b2, 8);
        // all-(-128) activations × all-(-128) weights: max-magnitude
        // positive accumulation, 256·16384 = 2^22 (under the 2^24 proof)
        let a3 = vec![-128i8; k];
        let b3 = vec![-128i8; k * 8];
        check(&a3, &b3, 8);
    }

    #[test]
    fn interleave_pads_with_zeros_and_offsets_correctly() {
        // 5×9 tile inside a 6×20 matrix at origin (1, 10)
        let (k, n) = (6usize, 20usize);
        let b = fill_i8(k * n, 42);
        let (kc0, kc_len, nc0, nc_len) = (1usize, 5usize, 10usize, 9usize);
        let mut tile = Vec::new();
        interleave_tile(&b, n, kc0, kc_len, nc0, nc_len, &mut tile);
        assert_eq!(tile.len(), padded_tile_len(kc_len, nc_len)); // 8 * 16
        // spot-check mapping: chunk for j-block 0, k-group 0, column 2,
        // byte 3 holds b[kc0+3, nc0+2]
        assert_eq!(tile[2 * K_GROUP + 3], b[(kc0 + 3) * n + nc0 + 2]);
        // k-padding byte (ki=5..7 rows of group 1) is zero
        assert_eq!(tile[K_GROUP * J_GROUP + 1], 0); // group 1, col 0, kk=1 -> ki=5
        // column padding block (j=9..15) is all zero in its lanes
        let blk1 = kc_len.div_ceil(K_GROUP) * K_GROUP * J_GROUP;
        for jj in 1..J_GROUP {
            for g in 0..2 {
                for kk in 0..K_GROUP {
                    assert_eq!(tile[blk1 + g * 32 + jj * K_GROUP + kk], 0);
                }
            }
        }
        // and the scalar walker agrees with a direct dot on the subtile
        let a = fill_i8(kc_len, 7);
        let sub: Vec<i8> = (0..kc_len)
            .flat_map(|ki| (0..nc_len).map(move |ji| b[(kc0 + ki) * n + (nc0 + ji)]))
            .collect();
        let want = naive_dot(&a, &sub, nc_len);
        let mut got = vec![0i32; nc_len];
        tile_dot_scalar(&a, &tile, &mut got);
        assert_eq!(got, want);
    }

    #[test]
    fn force_scalar_env_is_live() {
        // not asserting on the ambient env (other tests may set it);
        // just pin the parsing contract
        assert!(matches!(active_isa(), Isa::Scalar | Isa::Avx2 | Isa::Neon));
        assert_eq!(detected_isa(), detected_isa());
    }
}

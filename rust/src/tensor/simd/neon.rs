//! NEON i8×i8→i32 tile kernel: `vmull_s8` signed widening multiply (no
//! saturation hazard — products are exact in i16) + `vpadalq_s16`
//! pairwise accumulate into i32 lanes, recombined per column with
//! `vpaddq_s32` at block end. Reads the same interleaved layout as the
//! AVX2 path, one 32-byte chunk as four 8-byte halves.

use super::{J_GROUP, K_GROUP};
use crate::tensor::GEMM_KC;

#[cfg(target_arch = "aarch64")]
use std::arch::aarch64::*;

/// # Safety
/// Caller must have verified NEON support (baseline on AArch64, still
/// probed). `tile` must be the interleaved form of a
/// `a.len() × out.len()` tile, and `a.len() <= GEMM_KC`.
#[target_feature(enable = "neon")]
pub(super) unsafe fn tile_dot(a: &[i8], tile: &[i8], out: &mut [i32]) {
    let kc = a.len();
    debug_assert!(kc <= GEMM_KC, "activation strip exceeds KC");
    let kp = kc.div_ceil(K_GROUP) * K_GROUP;
    let groups = kp / K_GROUP;
    let nc = out.len();
    let np = nc.div_ceil(J_GROUP) * J_GROUP;
    debug_assert_eq!(tile.len(), kp * np);
    // duplicated activation groups: [a0,a1,a2,a3, a0,a1,a2,a3] per
    // 4-group, so one 8-byte load pairs with two adjacent columns
    let mut adup = [0i8; 2 * GEMM_KC];
    for g in 0..groups {
        for kk in 0..K_GROUP {
            let ki = g * K_GROUP + kk;
            let v = if ki < kc { a[ki] } else { 0 };
            adup[g * 2 * K_GROUP + kk] = v;
            adup[g * 2 * K_GROUP + K_GROUP + kk] = v;
        }
    }
    // SAFETY: NEON is available (caller contract, enforced by the
    // `#[target_feature]` gate). Each 8-byte `vld1_s8` stays in bounds:
    // `adup` holds `2 * GEMM_KC >= 2 * kp` duplicated bytes, and the four
    // tile loads cover `base + g*K_GROUP*J_GROUP + 32 <= kp*np ==
    // tile.len()` (asserted above). The stores target a local
    // `[i32; J_GROUP]`, two quadwords wide.
    unsafe {
        for j0 in (0..np).step_by(J_GROUP) {
            let base = (j0 / J_GROUP) * kp * J_GROUP;
            // two i32 lanes per column; vpaddq folds them at block end
            let mut acc01 = vdupq_n_s32(0);
            let mut acc23 = vdupq_n_s32(0);
            let mut acc45 = vdupq_n_s32(0);
            let mut acc67 = vdupq_n_s32(0);
            for g in 0..groups {
                let av = vld1_s8(adup.as_ptr().add(g * 2 * K_GROUP));
                let chunk = tile.as_ptr().add(base + g * K_GROUP * J_GROUP);
                acc01 = vpadalq_s16(acc01, vmull_s8(vld1_s8(chunk), av));
                acc23 = vpadalq_s16(acc23, vmull_s8(vld1_s8(chunk.add(8)), av));
                acc45 = vpadalq_s16(acc45, vmull_s8(vld1_s8(chunk.add(16)), av));
                acc67 = vpadalq_s16(acc67, vmull_s8(vld1_s8(chunk.add(24)), av));
            }
            let mut lanes = [0i32; J_GROUP];
            vst1q_s32(lanes.as_mut_ptr(), vpaddq_s32(acc01, acc23));
            vst1q_s32(lanes.as_mut_ptr().add(4), vpaddq_s32(acc45, acc67));
            for (jj, &lane) in lanes.iter().take((nc - j0).min(J_GROUP)).enumerate() {
                out[j0 + jj] += lane;
            }
        }
    }
}

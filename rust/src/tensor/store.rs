//! Weight storage backing for packed panels: owned heap vectors or
//! zero-copy views into a 64-byte-aligned artifact mapping.
//!
//! The plan compiler packs weight matrices once ([`super::PackedB`] /
//! [`super::PackedBi8`]); a compiled-plan artifact persists those exact
//! panel bytes 64-byte-aligned so a later process can reconstruct the
//! plan *without re-packing*. [`WeightStore`] is the abstraction that
//! makes kernels agnostic to where the panel bytes live: `Owned` wraps
//! the compile-time `Vec`, `Mapped` borrows a range of an
//! [`AlignedBytes`] buffer shared (via `Arc`) with every other panel of
//! the same artifact. Both deref to `&[T]`, so the GEMM inner loops are
//! untouched.
//!
//! # Zero-copy rules
//!
//! A `Mapped` store is only constructed over ranges whose byte offset is
//! a multiple of the element alignment (the artifact writer aligns every
//! section payload to 64 bytes, which covers every element type used
//! here), for element types where any bit pattern is a valid value
//! (`f32`, `i8`, `i32`). Those two facts make the byte→element cast in
//! `Deref` sound; they are checked at construction, not per access.

use std::alloc::{alloc_zeroed, dealloc, handle_alloc_error, Layout};
use std::sync::Arc;

/// Alignment guaranteed for [`AlignedBytes`] buffers and required of
/// every mapped section payload — one cache line, and a multiple of
/// every element alignment the panel formats use.
pub const WEIGHT_ALIGN: usize = 64;

/// A heap buffer of bytes guaranteed to start on a [`WEIGHT_ALIGN`]
/// boundary. This is the crate's "mapping": artifact loading reads the
/// whole file into one `AlignedBytes` and every weight panel borrows its
/// range from it through an `Arc` (no per-panel copy, no re-pack).
pub struct AlignedBytes {
    ptr: *mut u8,
    len: usize,
}

// SAFETY: AlignedBytes owns its allocation exclusively (the pointer is
// never aliased mutably after construction) and the payload is plain
// bytes, so moving or sharing the handle across threads is sound.
unsafe impl Send for AlignedBytes {}
// SAFETY: all access after construction is through &self (read-only).
unsafe impl Sync for AlignedBytes {}

impl AlignedBytes {
    /// Allocate a zeroed buffer of `len` bytes aligned to
    /// [`WEIGHT_ALIGN`]. A zero-length buffer allocates nothing.
    pub fn zeroed(len: usize) -> AlignedBytes {
        if len == 0 {
            return AlignedBytes { ptr: std::ptr::null_mut(), len: 0 };
        }
        let layout = Layout::from_size_align(len, WEIGHT_ALIGN)
            .expect("weight buffer layout must be constructible");
        // SAFETY: `layout` has non-zero size (len > 0 checked above) and
        // a valid power-of-two alignment.
        let ptr = unsafe { alloc_zeroed(layout) };
        if ptr.is_null() {
            handle_alloc_error(layout);
        }
        AlignedBytes { ptr, len }
    }

    /// Copy `bytes` into a fresh aligned buffer.
    pub fn from_slice(bytes: &[u8]) -> AlignedBytes {
        let buf = AlignedBytes::zeroed(bytes.len());
        if !bytes.is_empty() {
            // SAFETY: `buf.ptr` is a live allocation of exactly
            // `bytes.len()` bytes, disjoint from `bytes` (freshly
            // allocated above).
            unsafe { std::ptr::copy_nonoverlapping(bytes.as_ptr(), buf.ptr, bytes.len()) };
        }
        buf
    }

    pub fn len(&self) -> usize {
        self.len
    }

    pub fn is_empty(&self) -> bool {
        self.len == 0
    }

    /// The buffer contents.
    pub fn as_slice(&self) -> &[u8] {
        if self.len == 0 {
            return &[];
        }
        // SAFETY: `ptr` points at a live allocation of exactly `len`
        // initialized bytes (zeroed at alloc, possibly overwritten via
        // `as_mut_slice` before sharing).
        unsafe { std::slice::from_raw_parts(self.ptr, self.len) }
    }

    /// Mutable access for the loader to fill the buffer (before the
    /// buffer is shared behind an `Arc`).
    pub fn as_mut_slice(&mut self) -> &mut [u8] {
        if self.len == 0 {
            return &mut [];
        }
        // SAFETY: `&mut self` guarantees exclusive access; `ptr`/`len`
        // describe a live allocation of initialized bytes.
        unsafe { std::slice::from_raw_parts_mut(self.ptr, self.len) }
    }

    /// Whether `p` points into this buffer (pointer-provenance checks in
    /// the zero-copy tests: a loaded panel's data pointer must land in
    /// the artifact mapping, proving no re-pack copied it out).
    pub fn contains_ptr(&self, p: *const u8) -> bool {
        let base = self.ptr as usize;
        let q = p as usize;
        self.len > 0 && q >= base && q < base + self.len
    }
}

impl Drop for AlignedBytes {
    fn drop(&mut self) {
        if self.len == 0 {
            return;
        }
        let layout = Layout::from_size_align(self.len, WEIGHT_ALIGN)
            .expect("layout was constructible at alloc time");
        // SAFETY: `ptr` was allocated with exactly this layout in
        // `zeroed` and is only deallocated here, once.
        unsafe { dealloc(self.ptr, layout) };
    }
}

impl std::fmt::Debug for AlignedBytes {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("AlignedBytes").field("len", &self.len).finish()
    }
}

/// Element types a [`WeightStore`] may map from raw bytes: plain-old-data
/// scalars where **every bit pattern is a valid value**. Sealed to the
/// three panel element types the packed formats use.
pub trait PanelElem: Copy + PartialEq + std::fmt::Debug + private::Sealed + 'static {}
impl PanelElem for f32 {}
impl PanelElem for i8 {}
impl PanelElem for i32 {}
mod private {
    pub trait Sealed {}
    impl Sealed for f32 {}
    impl Sealed for i8 {}
    impl Sealed for i32 {}
}

/// Storage behind a packed weight panel: an owned vector (compile-time
/// packing) or a borrowed range of an artifact mapping (zero-copy load).
/// Derefs to `&[T]`, so kernel inner loops never see the difference.
#[derive(Clone)]
pub enum WeightStore<T: PanelElem> {
    /// Compile-time packed storage.
    Owned(Vec<T>),
    /// `len` elements starting `byte_off` bytes into `buf` — borrowed
    /// straight from the artifact mapping, never copied.
    Mapped { buf: Arc<AlignedBytes>, byte_off: usize, len: usize },
}

impl<T: PanelElem> WeightStore<T> {
    /// A zero-copy view of `len` elements at `byte_off` in `buf`.
    /// Panics when the range is out of bounds or `byte_off` is not
    /// aligned for `T` — the artifact loader validates section layout
    /// (64-byte alignment) *before* constructing stores, so a panic here
    /// is a loader bug, not a data error.
    pub fn mapped(buf: Arc<AlignedBytes>, byte_off: usize, len: usize) -> WeightStore<T> {
        let bytes = len * std::mem::size_of::<T>();
        assert!(
            byte_off % std::mem::align_of::<T>() == 0,
            "mapped weight range at byte {byte_off} is misaligned for the element type"
        );
        assert!(
            byte_off + bytes <= buf.len(),
            "mapped weight range {byte_off}..{} exceeds mapping length {}",
            byte_off + bytes,
            buf.len()
        );
        WeightStore::Mapped { buf, byte_off, len }
    }

    /// The panel contents.
    pub fn as_slice(&self) -> &[T] {
        match self {
            WeightStore::Owned(v) => v,
            WeightStore::Mapped { buf, byte_off, len } => {
                let p = buf.as_slice()[*byte_off..].as_ptr();
                // SAFETY: construction checked that `byte_off` is aligned
                // for `T` and that `len * size_of::<T>()` bytes fit in
                // `buf`; `T: PanelElem` guarantees every bit pattern is a
                // valid `T`; the backing `Arc` keeps `buf` alive for the
                // borrow's duration.
                unsafe { std::slice::from_raw_parts(p.cast::<T>(), *len) }
            }
        }
    }

    /// Whether this store borrows from an artifact mapping (zero-copy
    /// provenance introspection).
    pub fn is_mapped(&self) -> bool {
        matches!(self, WeightStore::Mapped { .. })
    }
}

impl<T: PanelElem> std::ops::Deref for WeightStore<T> {
    type Target = [T];
    fn deref(&self) -> &[T] {
        self.as_slice()
    }
}

impl<T: PanelElem> From<Vec<T>> for WeightStore<T> {
    fn from(v: Vec<T>) -> WeightStore<T> {
        WeightStore::Owned(v)
    }
}

impl<T: PanelElem> PartialEq for WeightStore<T> {
    fn eq(&self, other: &WeightStore<T>) -> bool {
        self.as_slice() == other.as_slice()
    }
}

impl<T: PanelElem> std::fmt::Debug for WeightStore<T> {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        let kind = if self.is_mapped() { "Mapped" } else { "Owned" };
        write!(f, "WeightStore::{kind}(len={})", self.as_slice().len())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn aligned_bytes_alignment_and_contents() {
        let mut b = AlignedBytes::zeroed(100);
        assert_eq!(b.len(), 100);
        assert_eq!(b.as_slice().as_ptr() as usize % WEIGHT_ALIGN, 0);
        assert!(b.as_slice().iter().all(|&x| x == 0));
        b.as_mut_slice()[3] = 7;
        assert_eq!(b.as_slice()[3], 7);
        let c = AlignedBytes::from_slice(&[1, 2, 3]);
        assert_eq!(c.as_slice(), &[1, 2, 3]);
        let empty = AlignedBytes::zeroed(0);
        assert!(empty.is_empty());
        assert_eq!(empty.as_slice(), &[] as &[u8]);
        assert!(!empty.contains_ptr(b.as_slice().as_ptr()));
    }

    #[test]
    fn contains_ptr_bounds() {
        let b = AlignedBytes::zeroed(16);
        let s = b.as_slice();
        assert!(b.contains_ptr(s.as_ptr()));
        assert!(b.contains_ptr(&s[15]));
        // one-past-the-end is NOT contained
        assert!(!b.contains_ptr(s.as_ptr().wrapping_add(16)));
    }

    #[test]
    fn owned_and_mapped_stores_agree() {
        let owned: WeightStore<f32> = vec![1.0f32, -2.5, 3.25].into();
        assert!(!owned.is_mapped());
        assert_eq!(&owned[..], &[1.0, -2.5, 3.25]);

        let mut buf = AlignedBytes::zeroed(64 + 12);
        // f32 values at byte offset 64
        for (i, v) in [1.0f32, -2.5, 3.25].iter().enumerate() {
            let bytes = v.to_le_bytes();
            buf.as_mut_slice()[64 + 4 * i..64 + 4 * i + 4].copy_from_slice(&bytes);
        }
        let arc = Arc::new(buf);
        let mapped: WeightStore<f32> = WeightStore::mapped(arc.clone(), 64, 3);
        assert!(mapped.is_mapped());
        assert_eq!(mapped, owned);
        assert!(arc.contains_ptr(mapped.as_slice().as_ptr().cast()));

        let m8: WeightStore<i8> = WeightStore::mapped(arc, 0, 4);
        assert_eq!(&m8[..], &[0i8, 0, 0, 0]);
    }

    #[test]
    #[should_panic(expected = "exceeds mapping length")]
    fn mapped_store_rejects_out_of_bounds() {
        let arc = Arc::new(AlignedBytes::zeroed(8));
        let _ = WeightStore::<i32>::mapped(arc, 0, 3);
    }

    #[test]
    #[should_panic(expected = "misaligned")]
    fn mapped_store_rejects_misalignment() {
        let arc = Arc::new(AlignedBytes::zeroed(64));
        let _ = WeightStore::<f32>::mapped(arc, 2, 4);
    }
}

//! Test utilities: deterministic random tensors and a lightweight
//! property-testing loop (proptest is not in the vendored crate set).

use crate::tensor::Tensor;
use crate::zoo::rng::Rng;

/// Random f32 tensor with values in `[lo, hi)`.
pub fn random_tensor(rng: &mut Rng, shape: Vec<usize>, lo: f32, hi: f32) -> Tensor {
    let n = shape.iter().product();
    Tensor::new(shape, (0..n).map(|_| rng.range(lo, hi)).collect())
}

/// Assert two tensors are elementwise close.
#[track_caller]
pub fn assert_close(a: &Tensor, b: &Tensor, tol: f32) {
    assert_eq!(a.shape(), b.shape(), "shape mismatch");
    let (av, bv) = (a.as_f32().unwrap(), b.as_f32().unwrap());
    for (i, (x, y)) in av.iter().zip(bv).enumerate() {
        assert!(
            (x - y).abs() <= tol,
            "element {i}: {x} vs {y} (tol {tol}, shapes {:?})",
            a.shape()
        );
    }
}

/// Poor-man's property test: run `f` over `cases` seeded inputs; panics
/// with the failing seed for reproduction.
pub fn for_all_seeds(cases: u64, f: impl Fn(&mut Rng)) {
    for seed in 1..=cases {
        let mut rng = Rng::new(seed.wrapping_mul(0x9E3779B97F4A7C15));
        let result = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| f(&mut rng)));
        if let Err(e) = result {
            eprintln!("property failed at seed {seed}");
            std::panic::resume_unwind(e);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn random_tensor_in_range() {
        let mut rng = Rng::new(1);
        let t = random_tensor(&mut rng, vec![4, 4], -2.0, 2.0);
        assert!(t.as_f32().unwrap().iter().all(|v| (-2.0..2.0).contains(v)));
    }

    #[test]
    #[should_panic]
    fn assert_close_catches_mismatch() {
        assert_close(&Tensor::scalar(1.0), &Tensor::scalar(2.0), 0.1);
    }

    #[test]
    fn for_all_seeds_runs() {
        let mut count = 0u64;
        // not capturing &mut across unwind boundary: use a cell
        let counter = std::cell::Cell::new(0u64);
        for_all_seeds(5, |_| counter.set(counter.get() + 1));
        count += counter.get();
        assert_eq!(count, 5);
    }
}

//! Chrome trace-event JSON export: serializes a [`TraceRecorder`] drain
//! into the `{"traceEvents": [...]}` object format that
//! `chrome://tracing` and Perfetto load directly.
//!
//! Mapping (the trace-event format's `ph` phases):
//! * one process (`pid` 1) named `qonnx`, one track per recorded thread
//!   (`tid` = registration order) named via `thread_name` metadata — so
//!   shard threads (`qonnx-shard-N`) and intra-op workers
//!   (`qonnx-intraop-N`) each get their own labeled row;
//! * [`EventKind::SpanBegin`]/[`EventKind::SpanEnd`] → `B`/`E` (nested
//!   per thread), [`EventKind::Complete`] → `X` with `dur`,
//!   [`EventKind::Instant`] → `i` (thread-scoped), [`EventKind::Counter`]
//!   → `C`;
//! * timestamps are microseconds with sub-µs precision kept as a
//!   fraction (`ts`/`dur` are µs floats in the format).

use super::{EventKind, ThreadTrace, TraceRecorder};
use std::fmt::Write as _;

// referenced by the module docs
#[allow(unused_imports)]
use super::TraceEvent;

fn esc(s: &str) -> String {
    let mut out = String::with_capacity(s.len());
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => {
                let _ = write!(out, "\\u{:04x}", c as u32);
            }
            c => out.push(c),
        }
    }
    out
}

fn args_json(args: &[Option<super::Arg>; 2]) -> String {
    let mut out = String::from("{");
    let mut first = true;
    for (k, v) in args.iter().flatten() {
        if !first {
            out.push(',');
        }
        first = false;
        let _ = write!(out, "\"{}\":{v}", esc(k));
    }
    out.push('}');
    out
}

/// Serialize a drain (see [`TraceRecorder::drain`]) to Chrome trace-event
/// JSON. The output is a complete, self-contained object — write it to a
/// file and load it in `chrome://tracing` or <https://ui.perfetto.dev>.
pub fn chrome_trace_json(traces: &[ThreadTrace]) -> String {
    let mut out = String::from("{\"traceEvents\":[\n");
    let mut first = true;
    let mut push = |s: String, out: &mut String| {
        if !first {
            out.push_str(",\n");
        }
        first = false;
        out.push_str(&s);
    };
    push(
        "{\"ph\":\"M\",\"pid\":1,\"tid\":0,\"name\":\"process_name\",\
         \"args\":{\"name\":\"qonnx\"}}"
            .to_string(),
        &mut out,
    );
    for t in traces {
        push(
            format!(
                "{{\"ph\":\"M\",\"pid\":1,\"tid\":{},\"name\":\"thread_name\",\
                 \"args\":{{\"name\":\"{}\"}}}}",
                t.tid,
                esc(&t.thread_name)
            ),
            &mut out,
        );
    }
    for t in traces {
        for e in &t.events {
            let ts = e.ts_ns as f64 / 1000.0;
            let common = format!(
                "\"pid\":1,\"tid\":{},\"ts\":{ts:.3},\"cat\":\"{}\",\"name\":\"{}\"",
                t.tid,
                esc(e.cat),
                esc(&e.name)
            );
            let ev = match e.kind {
                EventKind::SpanBegin => {
                    format!("{{\"ph\":\"B\",{common},\"args\":{}}}", args_json(&e.args))
                }
                EventKind::SpanEnd => format!("{{\"ph\":\"E\",{common}}}"),
                EventKind::Instant => format!(
                    "{{\"ph\":\"i\",\"s\":\"t\",{common},\"args\":{}}}",
                    args_json(&e.args)
                ),
                EventKind::Complete => format!(
                    "{{\"ph\":\"X\",{common},\"dur\":{:.3},\"args\":{}}}",
                    e.dur_ns as f64 / 1000.0,
                    args_json(&e.args)
                ),
                EventKind::Counter => {
                    format!("{{\"ph\":\"C\",{common},\"args\":{}}}", args_json(&e.args))
                }
            };
            push(ev, &mut out);
        }
    }
    out.push_str("\n]}\n");
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ir::json::Json;

    /// The acceptance test for the export shape: the emitted JSON parses
    /// with the crate's own parser and carries the structure Perfetto
    /// requires (`traceEvents` array; `ph`/`pid`/`tid`/`ts` per event;
    /// thread-name metadata; balanced B/E pairs; X events with `dur`).
    #[test]
    fn export_is_structurally_valid_chrome_trace() {
        let rec = TraceRecorder::new(64);
        {
            let _batch = rec.span("shard", "batch:full", &[("batch_size", 4)]);
            let _exec = rec.span("shard", "execute", &[]);
        }
        rec.instant("request", "shed \"quoted\"\n", &[("queue_depth", 7)]);
        rec.complete("request", "queued", 100, 2_500, &[]);
        rec.counter("queue", "queue_depth", 3);
        let dump = rec.drain();
        let text = chrome_trace_json(&dump);

        let parsed = Json::parse(&text).expect("chrome trace must be valid JSON");
        let events = parsed
            .req("traceEvents")
            .and_then(Json::as_arr)
            .expect("top-level traceEvents array");
        // 2 metadata (process + 1 thread) + 4 span begin/end + i + X + C
        assert_eq!(events.len(), 9);
        let mut depth = 0i64;
        let mut saw_thread_name = false;
        let mut saw_complete_dur = false;
        for e in events {
            let ph = e.req("ph").and_then(Json::as_str).expect("every event has ph");
            assert!(e.req("pid").and_then(Json::as_i64).is_ok());
            assert!(e.req("tid").and_then(Json::as_i64).is_ok());
            match ph {
                "M" => {
                    if e.req("name").and_then(Json::as_str).unwrap() == "thread_name" {
                        saw_thread_name = true;
                    }
                }
                "B" => depth += 1,
                "E" => {
                    depth -= 1;
                    assert!(depth >= 0, "E without matching B");
                }
                "X" => {
                    let dur = e.req("dur").and_then(Json::as_f64).expect("X carries dur");
                    assert!((dur - 2.5).abs() < 1e-9, "dur is µs: {dur}");
                    saw_complete_dur = true;
                }
                "i" => assert_eq!(e.req("s").and_then(Json::as_str).unwrap(), "t"),
                "C" => {
                    let v = e
                        .req("args")
                        .and_then(|a| a.req("value"))
                        .and_then(Json::as_i64)
                        .unwrap();
                    assert_eq!(v, 3);
                }
                other => panic!("unexpected phase {other}"),
            }
            if ph != "M" {
                assert!(e.req("ts").and_then(Json::as_f64).is_ok(), "ts required");
            }
        }
        assert_eq!(depth, 0, "spans unbalanced in export");
        assert!(saw_thread_name && saw_complete_dur);
    }

    #[test]
    fn escaping_survives_hostile_names() {
        assert_eq!(esc("a\"b\\c\nd"), "a\\\"b\\\\c\\nd");
        assert_eq!(esc("\u{1}"), "\\u0001");
    }
}

//! Execution tracing & profiling: typed events in per-thread ring buffers,
//! request-lifecycle spans, per-step kernel profiles, and Chrome-trace /
//! Prometheus exporters.
//!
//! # Recorder contract
//!
//! A [`TraceRecorder`] owns one bounded ring buffer per participating
//! thread. Producers never contend with each other: each thread records
//! into its own buffer (found through a thread-local cache after the
//! first event), so the per-event cost is one uncontended `Mutex` lock of
//! a buffer only its owner and a drainer ever touch — "lock-free enough"
//! for a hot loop that measures in microseconds per step. Memory is
//! bounded: when a ring is full the OLDEST event is overwritten and the
//! buffer's `dropped` counter is incremented exactly once per loss, so
//! `drained events + dropped` always equals the number recorded.
//! Timestamps come from one monotonic [`std::time::Instant`] epoch per
//! recorder (`ts_ns`), never the wall clock.
//!
//! Tracing is **instance-based and opt-in**: every producer site holds an
//! `Option<Arc<TraceRecorder>>` and the disabled path is a single branch
//! on `None` — no atomics, no allocation, no syscalls — so executors keep
//! their untraced speed (asserted by the `make bench` overhead section).
//! The one process-global hook, [`install_global`]/[`global`], exists so
//! the CLI can hand the intra-op worker pool a recorder to register its
//! threads with (one named track per worker); it is an `AtomicBool` load
//! on the never-installed path and is NOT consulted by the executors.
//!
//! # Span taxonomy
//!
//! Spans nest per thread (Chrome `B`/`E` semantics); [`SpanGuard`] ends
//! its span on `Drop`, so spans stay balanced even when an engine panic
//! unwinds through a shard (asserted under `FaultyEngine` in
//! `tests/serving_faults.rs`). The stack uses:
//!
//! | cat       | name                | kind     | meaning                               |
//! |-----------|---------------------|----------|---------------------------------------|
//! | `request` | `admit` / `shed`    | instant  | admission outcome (+ queue depth)     |
//! | `request` | `queued`            | complete | queue wait, enqueue → drain           |
//! | `request` | typed failure name  | instant  | `deadline-exceeded`, `engine-error`,  |
//! |           |                     |          | `shard-panic`, `shutdown`, ...        |
//! | `shard`   | `batch:<reason>`    | span     | one formed batch; reason is the close |
//! |           |                     |          | cause (full/window/deadline/shutdown) |
//! | `shard`   | `execute`/`scatter` | span     | engine call / response delivery       |
//! | `shard`   | `shard-restart`     | instant  | supervisor respawned a dead shard     |
//! | `exec`    | kernel tag          | complete | one plan step (from [`crate::plan::StepObserver`]) |
//! | `queue`   | `queue_depth`       | counter  | depth after each admission            |
//! | `pool`    | `worker-online`     | instant  | intra-op worker registered its track  |
//!
//! [`chrome::chrome_trace_json`] serializes a drain into Chrome
//! trace-event JSON (`chrome://tracing` / Perfetto; one track per shard
//! and per worker thread); [`profile::StepProfile`] aggregates executor
//! step samples against the Eq.-5 static model into a per-kernel
//! achieved-GMAC/s table.

pub mod chrome;
pub mod profile;

use std::borrow::Cow;
use std::cell::RefCell;
use std::collections::VecDeque;
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{Arc, Mutex, MutexGuard, OnceLock, PoisonError};
use std::time::Instant;

/// One `(key, value)` annotation attached to an event. Values are kept
/// integral so serialization never meets NaN.
pub type Arg = (&'static str, i64);

/// The typed event vocabulary.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum EventKind {
    /// Opens a span on the recording thread (Chrome `B`).
    SpanBegin,
    /// Closes the innermost open span with the same name (Chrome `E`).
    SpanEnd,
    /// A point-in-time marker (Chrome `i`).
    Instant,
    /// A retroactive span recorded at its end: `ts_ns` is the start and
    /// `dur_ns` the length (Chrome `X`). Used where begin and end happen
    /// on different threads (queue wait) or are only known after the
    /// fact (executor step timing).
    Complete,
    /// A sampled numeric series (Chrome `C`); the value rides in
    /// `args[0]`.
    Counter,
}

/// A recorded event. `ts_ns` is nanoseconds since the recorder's epoch.
#[derive(Debug, Clone)]
pub struct TraceEvent {
    pub kind: EventKind,
    pub cat: &'static str,
    pub name: Cow<'static, str>,
    pub ts_ns: u64,
    /// Span length for [`EventKind::Complete`], zero otherwise.
    pub dur_ns: u64,
    pub args: [Option<Arg>; 2],
}

struct Ring {
    events: VecDeque<TraceEvent>,
    cap: usize,
}

/// One thread's bounded event buffer.
struct ThreadBuf {
    tid: u64,
    name: String,
    ring: Mutex<Ring>,
    dropped: AtomicU64,
}

/// Everything one thread contributed to a drain.
#[derive(Debug, Clone)]
pub struct ThreadTrace {
    /// Stable per-recorder track id (registration order, from 1).
    pub tid: u64,
    /// The OS thread name at registration time (`qonnx-shard-0`,
    /// `qonnx-intraop-3`, ...), or `"thread"` for unnamed threads.
    pub thread_name: String,
    pub events: Vec<TraceEvent>,
    /// Events lost to ring overwrite since the previous drain — exact.
    pub dropped: u64,
}

fn lock<T>(m: &Mutex<T>) -> MutexGuard<'_, T> {
    m.lock().unwrap_or_else(PoisonError::into_inner)
}

thread_local! {
    /// recorder-id → this thread's buffer, so steady-state recording
    /// never touches the recorder's registry lock.
    static BUF_CACHE: RefCell<Vec<(u64, Arc<ThreadBuf>)>> = const { RefCell::new(Vec::new()) };
}

static NEXT_RECORDER_ID: AtomicU64 = AtomicU64::new(1);

/// Bounded-memory, per-thread-buffered event recorder. See the module
/// docs for the contract; clone the `Arc` freely — all methods take
/// `&self`.
pub struct TraceRecorder {
    id: u64,
    epoch: Instant,
    cap: usize,
    bufs: Mutex<Vec<Arc<ThreadBuf>>>,
    next_tid: AtomicU64,
}

impl std::fmt::Debug for TraceRecorder {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("TraceRecorder")
            .field("events_per_thread", &self.cap)
            .field("threads", &lock(&self.bufs).len())
            .finish_non_exhaustive()
    }
}

impl TraceRecorder {
    /// A recorder whose per-thread rings hold `events_per_thread` events
    /// (floored at 8). Total memory is bounded by
    /// `threads × events_per_thread × sizeof(TraceEvent)`.
    pub fn new(events_per_thread: usize) -> Self {
        TraceRecorder {
            id: NEXT_RECORDER_ID.fetch_add(1, Ordering::Relaxed),
            epoch: Instant::now(),
            cap: events_per_thread.max(8),
            bufs: Mutex::new(Vec::new()),
            next_tid: AtomicU64::new(1),
        }
    }

    /// Nanoseconds since this recorder's epoch, from the monotonic clock.
    pub fn now_ns(&self) -> u64 {
        self.epoch.elapsed().as_nanos() as u64
    }

    /// Epoch-relative timestamp for an externally captured [`Instant`]
    /// (saturates to 0 for instants predating the recorder).
    pub fn ns_since_epoch(&self, t: Instant) -> u64 {
        t.checked_duration_since(self.epoch).map_or(0, |d| d.as_nanos() as u64)
    }

    /// Register the calling thread so it gets a (named) track even if it
    /// never records an event itself — the worker pool calls this.
    pub fn register_current_thread(&self) {
        let _ = self.buf();
    }

    fn buf(&self) -> Arc<ThreadBuf> {
        BUF_CACHE.with(|c| {
            let mut cache = c.borrow_mut();
            if let Some((_, b)) = cache.iter().find(|(id, _)| *id == self.id) {
                return b.clone();
            }
            let name = std::thread::current().name().unwrap_or("thread").to_string();
            let buf = Arc::new(ThreadBuf {
                tid: self.next_tid.fetch_add(1, Ordering::Relaxed),
                name,
                ring: Mutex::new(Ring {
                    events: VecDeque::with_capacity(self.cap.min(1024)),
                    cap: self.cap,
                }),
                dropped: AtomicU64::new(0),
            });
            lock(&self.bufs).push(buf.clone());
            // long-lived threads meet many short-lived recorders (unit
            // tests); keep the cache bounded by evicting oldest entries
            if cache.len() >= 8 {
                cache.remove(0);
            }
            cache.push((self.id, buf.clone()));
            buf
        })
    }

    fn record(&self, ev: TraceEvent) {
        let buf = self.buf();
        let mut ring = lock(&buf.ring);
        if ring.events.len() == ring.cap {
            ring.events.pop_front();
            buf.dropped.fetch_add(1, Ordering::Relaxed);
        }
        ring.events.push_back(ev);
    }

    fn pack(args: &[Arg]) -> [Option<Arg>; 2] {
        debug_assert!(args.len() <= 2, "events carry at most two args");
        [args.first().copied(), args.get(1).copied()]
    }

    /// Record a point-in-time marker.
    pub fn instant(&self, cat: &'static str, name: impl Into<Cow<'static, str>>, args: &[Arg]) {
        self.record(TraceEvent {
            kind: EventKind::Instant,
            cat,
            name: name.into(),
            ts_ns: self.now_ns(),
            dur_ns: 0,
            args: Self::pack(args),
        });
    }

    /// Record a sampled numeric series value.
    pub fn counter(&self, cat: &'static str, name: impl Into<Cow<'static, str>>, value: i64) {
        self.record(TraceEvent {
            kind: EventKind::Counter,
            cat,
            name: name.into(),
            ts_ns: self.now_ns(),
            dur_ns: 0,
            args: [Some(("value", value)), None],
        });
    }

    /// Record a retroactive span: `start_ns` is epoch-relative (see
    /// [`Self::now_ns`]/[`Self::ns_since_epoch`]).
    pub fn complete(
        &self,
        cat: &'static str,
        name: impl Into<Cow<'static, str>>,
        start_ns: u64,
        dur_ns: u64,
        args: &[Arg],
    ) {
        self.record(TraceEvent {
            kind: EventKind::Complete,
            cat,
            name: name.into(),
            ts_ns: start_ns,
            dur_ns,
            args: Self::pack(args),
        });
    }

    /// Open a span on the calling thread; the returned guard records the
    /// matching end on `Drop` (including during unwinding, so panics
    /// cannot leave a span dangling).
    pub fn span(
        &self,
        cat: &'static str,
        name: impl Into<Cow<'static, str>>,
        args: &[Arg],
    ) -> SpanGuard<'_> {
        let name = name.into();
        self.record(TraceEvent {
            kind: EventKind::SpanBegin,
            cat,
            name: name.clone(),
            ts_ns: self.now_ns(),
            dur_ns: 0,
            args: Self::pack(args),
        });
        SpanGuard { rec: self, cat, name }
    }

    /// Take every buffered event (one [`ThreadTrace`] per registered
    /// thread, registration order) and reset the per-thread dropped
    /// counters. With producers quiescent,
    /// `events drained (ever) + dropped (ever) == events recorded`.
    pub fn drain(&self) -> Vec<ThreadTrace> {
        let bufs = lock(&self.bufs).clone();
        bufs.iter()
            .map(|b| {
                let events: Vec<TraceEvent> = lock(&b.ring).events.drain(..).collect();
                ThreadTrace {
                    tid: b.tid,
                    thread_name: b.name.clone(),
                    events,
                    dropped: b.dropped.swap(0, Ordering::Relaxed),
                }
            })
            .collect()
    }
}

/// RAII guard for an open span; records [`EventKind::SpanEnd`] on drop.
pub struct SpanGuard<'a> {
    rec: &'a TraceRecorder,
    cat: &'static str,
    name: Cow<'static, str>,
}

impl Drop for SpanGuard<'_> {
    fn drop(&mut self) {
        self.rec.record(TraceEvent {
            kind: EventKind::SpanEnd,
            cat: self.cat,
            name: self.name.clone(),
            ts_ns: self.rec.now_ns(),
            dur_ns: 0,
            args: [None, None],
        });
    }
}

static GLOBAL_ON: AtomicBool = AtomicBool::new(false);
static GLOBAL: OnceLock<Arc<TraceRecorder>> = OnceLock::new();

/// Install the process-global recorder (first caller wins; returns
/// whether this call installed it). Only the CLI does this — it lets
/// intra-op pool workers spawned LATER register their tracks. Install
/// before the first inference so the lazily created pool sees it.
pub fn install_global(rec: Arc<TraceRecorder>) -> bool {
    let installed = GLOBAL.set(rec).is_ok();
    if installed {
        GLOBAL_ON.store(true, Ordering::Release);
    }
    installed
}

/// The installed global recorder, if any. One relaxed-ish atomic load on
/// the common never-installed path.
pub fn global() -> Option<&'static Arc<TraceRecorder>> {
    if !GLOBAL_ON.load(Ordering::Acquire) {
        return None;
    }
    GLOBAL.get()
}

/// Called by intra-op pool workers at startup: register a named track
/// with the global recorder when one is installed, else a no-op.
pub(crate) fn register_worker_thread() {
    if let Some(t) = global() {
        t.register_current_thread();
        t.instant("pool", "worker-online", &[]);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn ring_wraparound_keeps_newest_and_counts_drops_exactly() {
        let rec = TraceRecorder::new(8);
        for i in 0..20u64 {
            rec.counter("t", format!("e{i}"), i as i64);
        }
        let dump = rec.drain();
        assert_eq!(dump.len(), 1);
        let t = &dump[0];
        assert_eq!(t.events.len(), 8, "ring holds exactly its capacity");
        assert_eq!(t.dropped, 12, "dropped counter is exact");
        let names: Vec<&str> = t.events.iter().map(|e| e.name.as_ref()).collect();
        let want: Vec<String> = (12..20).map(|i| format!("e{i}")).collect();
        assert_eq!(names, want.iter().map(String::as_str).collect::<Vec<_>>());
        // a second drain returns empty buffers, not stale events
        let again = rec.drain();
        assert!(again[0].events.is_empty() && again[0].dropped == 0);
    }

    #[test]
    fn concurrent_producers_drain_without_loss_miscounts() {
        let rec = Arc::new(TraceRecorder::new(64));
        let per_thread = 1000u64;
        let threads: Vec<_> = (0..4)
            .map(|t| {
                let r = rec.clone();
                std::thread::Builder::new()
                    .name(format!("trace-prod-{t}"))
                    .spawn(move || {
                        for i in 0..per_thread {
                            r.instant("t", "tick", &[("i", i as i64)]);
                        }
                    })
                    .unwrap()
            })
            .collect();
        for h in threads {
            h.join().unwrap();
        }
        let dump = rec.drain();
        assert_eq!(dump.len(), 4);
        for t in &dump {
            assert!(t.events.len() <= 64);
            assert_eq!(
                t.events.len() as u64 + t.dropped,
                per_thread,
                "thread {} lost events without counting them",
                t.thread_name
            );
            assert!(t.thread_name.starts_with("trace-prod-"));
        }
        let tids: Vec<u64> = dump.iter().map(|t| t.tid).collect();
        let mut uniq = tids.clone();
        uniq.sort_unstable();
        uniq.dedup();
        assert_eq!(uniq.len(), 4, "tids must be distinct: {tids:?}");
    }

    #[test]
    fn span_guard_balances_on_panic_unwind() {
        let rec = Arc::new(TraceRecorder::new(64));
        let r = rec.clone();
        let result = std::panic::catch_unwind(std::panic::AssertUnwindSafe(move || {
            let _outer = r.span("t", "outer", &[]);
            let _inner = r.span("t", "inner", &[]);
            panic!("boom");
        }));
        assert!(result.is_err());
        let events = rec.drain().remove(0).events;
        let mut stack: Vec<String> = Vec::new();
        for e in &events {
            match e.kind {
                EventKind::SpanBegin => stack.push(e.name.to_string()),
                EventKind::SpanEnd => {
                    assert_eq!(stack.pop().as_deref(), Some(e.name.as_ref()));
                }
                _ => {}
            }
        }
        assert!(stack.is_empty(), "unwind left dangling spans: {stack:?}");
        assert_eq!(events.len(), 4, "outer+inner begin/end");
    }

    #[test]
    fn timestamps_are_monotonic_per_thread() {
        let rec = TraceRecorder::new(128);
        for _ in 0..50 {
            rec.instant("t", "tick", &[]);
        }
        let events = rec.drain().remove(0).events;
        for w in events.windows(2) {
            assert!(w[0].ts_ns <= w[1].ts_ns);
        }
    }

    #[test]
    fn global_is_none_until_installed() {
        // never installed in the library test binary unless this test
        // (or a CLI path) installs it; check the cheap path works
        let _ = global();
        let _ = install_global(Arc::new(TraceRecorder::new(8)));
        assert!(global().is_some());
        // second install loses
        assert!(!install_global(Arc::new(TraceRecorder::new(8))));
    }
}

//! Per-step profile aggregation: joins executor timings
//! ([`crate::plan::StepSample`], collected by
//! [`crate::plan::StepObserver`]) with the paper's static complexity
//! model ([`crate::metrics::ModelReport`] — Eq. 5 BOPs, Baskin et
//! al.'s metric) into a roofline-style achieved-throughput report.
//!
//! FINN-R (see `PAPERS.md`) drives optimization by comparing
//! *predicted* per-layer cost against *achieved* throughput; this
//! module computes the achieved side. Samples from repeated profiled
//! runs are aggregated per schedule step (mean wall time, share of the
//! whole plan, arena fresh-alloc vs pool-reuse counts), and every step
//! whose producing node has an entry in the static report additionally
//! gets achieved GMAC/s and effective GBOP/s — MACs and BOPs scale
//! linearly with the leading batch dim, so a batch-`n` run is credited
//! `n×` the per-sample work. Steps without a static entry (pools,
//! reshapes, thresholds) show wall time only.

use crate::metrics::ModelReport;
use crate::plan::StepSample;
use std::collections::BTreeMap;
use std::fmt::Write as _;

/// One schedule step's aggregated profile (over all recorded runs).
#[derive(Debug, Clone)]
pub struct StepRow {
    /// Schedule step index (matches [`crate::plan::ExecutionPlan`]'s
    /// `summary()` listing).
    pub step: usize,
    /// Name of the node whose kernel ran (the dispatch node of a fused
    /// chain) — the join key against [`ModelReport`] layers.
    pub node_name: String,
    /// Kernel display tag (`qconv`, `packed-gemm`, `generic:Relu`, …).
    pub kernel: String,
    /// Number of recorded executions.
    pub calls: u64,
    /// Total wall time across all calls, nanoseconds.
    pub total_ns: u64,
    /// Mean wall time per call, microseconds.
    pub mean_us: f64,
    /// Fraction of whole-plan recorded time (0..=1).
    pub share: f64,
    /// Static per-call MACs (Eq. 5 inputs, scaled by batch); `None`
    /// when the node has no entry in the static report.
    pub macs: Option<u64>,
    /// Static per-call BOPs (Eq. 5, scaled by batch); `None` as above.
    pub bops: Option<f64>,
    /// Achieved giga-MACs per second (0 when `macs` is `None`).
    pub gmac_s: f64,
    /// Effective giga-bit-ops per second (0 when `bops` is `None`).
    pub gbop_s: f64,
    /// Fresh arena allocations attributed to this step (all calls).
    pub arena_allocs: u64,
    /// Arena pool reuses attributed to this step (all calls).
    pub arena_reuses: u64,
}

/// Aggregated per-step profile for one plan, joined against the static
/// complexity model. Build from executor samples with
/// [`StepProfile::build`]; render with [`StepProfile::render_table`].
#[derive(Debug, Clone)]
pub struct StepProfile {
    /// Model name (for the table header).
    pub model: String,
    /// Kernel substrate description (ISA, intra-op threads).
    pub substrate: String,
    /// Leading batch dim the samples ran at.
    pub batch: u64,
    /// Profiled run count (max calls over steps).
    pub runs: u64,
    /// Per-step rows, in schedule order.
    pub rows: Vec<StepRow>,
    /// Total recorded wall time across all rows and runs, nanoseconds.
    pub total_ns: u64,
}

fn substrate_string() -> String {
    format!(
        "isa {} ({}), intra-op threads {}",
        crate::tensor::simd::active_isa(),
        if crate::tensor::simd::force_scalar() { "forced scalar" } else { "detected" },
        crate::runtime::pool::effective_parallelism()
    )
}

fn trunc(s: &str, max: usize) -> String {
    if s.chars().count() <= max {
        s.to_string()
    } else {
        let mut out: String = s.chars().take(max.saturating_sub(1)).collect();
        out.push('…');
        out
    }
}

impl StepProfile {
    /// Aggregate raw executor samples (possibly spanning many runs)
    /// into per-step rows, joining each step's node name against the
    /// static `report` (when given) to compute achieved GMAC/s and
    /// GBOP/s. `batch` scales the static per-sample MACs/BOPs to the
    /// batch the samples actually executed.
    pub fn build(
        model: &str,
        samples: &[StepSample],
        report: Option<&ModelReport>,
        batch: u64,
    ) -> StepProfile {
        struct Acc {
            node_name: String,
            kernel: String,
            calls: u64,
            total_ns: u64,
            arena_allocs: u64,
            arena_reuses: u64,
        }
        let mut by_step: BTreeMap<usize, Acc> = BTreeMap::new();
        for s in samples {
            let a = by_step.entry(s.step).or_insert_with(|| Acc {
                node_name: s.node_name.clone(),
                kernel: s.kernel.clone(),
                calls: 0,
                total_ns: 0,
                arena_allocs: 0,
                arena_reuses: 0,
            });
            a.calls += 1;
            a.total_ns += s.wall_ns;
            a.arena_allocs += s.arena_allocs;
            a.arena_reuses += s.arena_reuses;
        }
        let total_ns: u64 = by_step.values().map(|a| a.total_ns).sum();
        let runs = by_step.values().map(|a| a.calls).max().unwrap_or(0);
        let rows = by_step
            .into_iter()
            .map(|(step, a)| {
                let mean_ns =
                    if a.calls > 0 { a.total_ns as f64 / a.calls as f64 } else { 0.0 };
                let layer =
                    report.and_then(|r| r.layers.iter().find(|l| l.node_name == a.node_name));
                let macs = layer.map(|l| l.macs.saturating_mul(batch));
                let bops = layer.map(|l| l.bops * batch as f64);
                let per_call_s = mean_ns / 1e9;
                let gmac_s = match macs {
                    Some(m) if per_call_s > 0.0 => m as f64 / per_call_s / 1e9,
                    _ => 0.0,
                };
                let gbop_s = match bops {
                    Some(b) if per_call_s > 0.0 => b / per_call_s / 1e9,
                    _ => 0.0,
                };
                StepRow {
                    step,
                    node_name: a.node_name,
                    kernel: a.kernel,
                    calls: a.calls,
                    total_ns: a.total_ns,
                    mean_us: mean_ns / 1000.0,
                    share: if total_ns > 0 {
                        a.total_ns as f64 / total_ns as f64
                    } else {
                        0.0
                    },
                    macs,
                    bops,
                    gmac_s,
                    gbop_s,
                    arena_allocs: a.arena_allocs,
                    arena_reuses: a.arena_reuses,
                }
            })
            .collect();
        StepProfile {
            model: model.to_string(),
            substrate: substrate_string(),
            batch,
            runs,
            rows,
            total_ns,
        }
    }

    /// Whole-plan achieved GMAC/s: the sum of every statically-modeled
    /// step's MACs, over the whole plan's mean per-run wall time (so
    /// un-modeled steps — pools, reshapes — *count against* throughput,
    /// as they do in a real deployment).
    pub fn total_gmac_s(&self) -> f64 {
        if self.runs == 0 || self.total_ns == 0 {
            return 0.0;
        }
        let macs: u64 = self.rows.iter().filter_map(|r| r.macs).sum();
        let per_run_s = self.total_ns as f64 / self.runs as f64 / 1e9;
        macs as f64 / per_run_s / 1e9
    }

    /// Whole-plan effective GBOP/s (Eq.-5 BOPs over mean per-run time).
    pub fn total_gbop_s(&self) -> f64 {
        if self.runs == 0 || self.total_ns == 0 {
            return 0.0;
        }
        let bops: f64 = self.rows.iter().filter_map(|r| r.bops).sum();
        let per_run_s = self.total_ns as f64 / self.runs as f64 / 1e9;
        bops / per_run_s / 1e9
    }

    /// Render the per-step table the `qonnx profile` CLI prints:
    /// time, share, achieved GMAC/s + GBOP/s (`-` where the static
    /// model has no entry), arena alloc/reuse counts, then the plan
    /// total and the kernel substrate line.
    pub fn render_table(&self) -> String {
        let mut s =
            format!("profile '{}' (batch {}, {} runs)\n", self.model, self.batch, self.runs);
        let _ = writeln!(
            s,
            "  {:<5} {:<20} {:<24} {:>10} {:>6} {:>8} {:>8}  {}",
            "step", "kernel", "node", "mean µs", "%", "GMAC/s", "GBOP/s", "alloc/reuse"
        );
        for r in &self.rows {
            let gm = r.macs.map(|_| format!("{:.2}", r.gmac_s)).unwrap_or_else(|| "-".into());
            let gb = r.bops.map(|_| format!("{:.2}", r.gbop_s)).unwrap_or_else(|| "-".into());
            let _ = writeln!(
                s,
                "  s{:<4} {:<20} {:<24} {:>10.1} {:>5.1}% {:>8} {:>8}  {}/{}",
                r.step,
                trunc(&r.kernel, 20),
                trunc(&r.node_name, 24),
                r.mean_us,
                r.share * 100.0,
                gm,
                gb,
                r.arena_allocs,
                r.arena_reuses
            );
        }
        let per_run_us =
            if self.runs > 0 { self.total_ns as f64 / self.runs as f64 / 1000.0 } else { 0.0 };
        let _ = writeln!(
            s,
            "  TOTAL {per_run_us:.1} µs/run  {:.2} GMAC/s  {:.2} GBOP/s",
            self.total_gmac_s(),
            self.total_gbop_s()
        );
        let _ = writeln!(s, "  substrate: {}", self.substrate);
        s
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::metrics::{LayerReport, ModelReport};

    fn sample(step: usize, node: &str, kernel: &str, wall_ns: u64) -> StepSample {
        StepSample {
            step,
            node_name: node.to_string(),
            op_type: "Conv".to_string(),
            kernel: kernel.to_string(),
            wall_ns,
            arena_allocs: 1,
            arena_reuses: 2,
        }
    }

    fn report() -> ModelReport {
        ModelReport {
            model_name: "m".to_string(),
            layers: vec![LayerReport {
                node_name: "conv0".to_string(),
                op_type: "Conv".to_string(),
                macs: 1_000_000,
                bops: 4_000_000.0,
                mac_bops: 4.0,
                weights: 100,
                weight_bits: 2,
                act_bits: 2,
            }],
        }
    }

    #[test]
    fn aggregates_runs_and_joins_static_model() {
        // two runs of a two-step plan; conv0 joins the report, relu not
        let samples = vec![
            sample(0, "conv0", "qconv", 1_000_000),
            sample(1, "relu0", "generic:Relu", 500_000),
            sample(0, "conv0", "qconv", 3_000_000),
            sample(1, "relu0", "generic:Relu", 500_000),
        ];
        let r = report();
        let p = StepProfile::build("m", &samples, Some(&r), 2);
        assert_eq!(p.runs, 2);
        assert_eq!(p.rows.len(), 2);
        assert_eq!(p.total_ns, 5_000_000);

        let conv = &p.rows[0];
        assert_eq!(conv.step, 0);
        assert_eq!(conv.calls, 2);
        // mean 2 ms; batch-2 MACs = 2e6 -> 2e6 / 2e-3 s = 1e9 = 1 GMAC/s
        assert!((conv.mean_us - 2000.0).abs() < 1e-9);
        assert_eq!(conv.macs, Some(2_000_000));
        assert!((conv.gmac_s - 1.0).abs() < 1e-9, "{}", conv.gmac_s);
        assert!((conv.gbop_s - 4.0).abs() < 1e-9, "{}", conv.gbop_s);
        assert!((conv.share - 0.8).abs() < 1e-9);

        let relu = &p.rows[1];
        assert_eq!(relu.macs, None);
        assert_eq!(relu.gmac_s, 0.0);
        assert!((relu.share - 0.2).abs() < 1e-9);

        // whole-plan: 2e6 MACs over 2.5 ms mean run = 0.8 GMAC/s
        assert!((p.total_gmac_s() - 0.8).abs() < 1e-9, "{}", p.total_gmac_s());

        let table = p.render_table();
        assert!(table.contains("qconv"), "{table}");
        assert!(table.contains("GMAC/s"), "{table}");
        assert!(table.contains("TOTAL"), "{table}");
        assert!(table.contains("substrate: isa"), "{table}");
        // the unmodeled step renders '-' in the throughput columns
        assert!(table.contains(" - "), "{table}");
    }

    #[test]
    fn empty_samples_produce_empty_but_renderable_profile() {
        let p = StepProfile::build("empty", &[], None, 1);
        assert_eq!(p.runs, 0);
        assert!(p.rows.is_empty());
        assert_eq!(p.total_gmac_s(), 0.0);
        let t = p.render_table();
        assert!(t.contains("TOTAL"), "{t}");
    }
}

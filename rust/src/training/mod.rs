//! Quantization-aware training substrate (DESIGN.md §3: the Brevitas/QKeras
//! stand-in).
//!
//! A from-scratch MLP QAT trainer with straight-through-estimator
//! gradients, used to produce *trained* low-precision models for the
//! Table III / Fig. 5 accuracy axis and the end-to-end pipeline example.
//! Weight quantizers: bipolar (XNOR-style, scale = mean |w|) or narrow
//! symmetric int-N; activation quantizers: sign (a1) or symmetric int-N
//! with an EMA-calibrated scale. Exports directly into the zoo's TFC graph
//! builder, so the trained network *is* a QONNX model.

mod quantizers;

pub use quantizers::{act_scale_from_max, quantize_act, quantize_weights, QuantizedWeights};

use crate::zoo::rng::Rng;
use crate::zoo::synth_data::Dataset;
use crate::zoo::{tfc_batch, DenseParams, TfcParams};
use anyhow::{ensure, Result};

/// Trainer configuration.
#[derive(Debug, Clone)]
pub struct QatConfig {
    pub weight_bits: u32,
    pub act_bits: u32,
    pub hidden: Vec<usize>,
    pub lr: f32,
    pub momentum: f32,
    pub epochs: usize,
    pub batch: usize,
    pub seed: u64,
}

impl QatConfig {
    /// TFC-shaped config (three hidden layers of 64).
    pub fn tfc(weight_bits: u32, act_bits: u32) -> QatConfig {
        QatConfig {
            weight_bits,
            act_bits,
            hidden: vec![64, 64, 64],
            lr: 0.02,
            momentum: 0.9,
            epochs: 20,
            batch: 32,
            seed: 0xF1AA,
        }
    }
}

/// One dense QAT layer.
struct Layer {
    w: Vec<f32>, // [fin, fout] row-major (latent float weights)
    vw: Vec<f32>,
    /// pre-activation bias (float; plays BatchNorm's centering role —
    /// essential for sign activations, harmless otherwise)
    b: Vec<f32>,
    vb: Vec<f32>,
    fin: usize,
    fout: usize,
    /// activation clip range (fixed 1.0 — Brevitas hardtanh convention)
    act_max: f32,
    quantize_act: bool,
}

/// A trained QAT MLP.
pub struct TrainedMlp {
    dims: Vec<usize>,
    layers: Vec<Layer>,
    pub weight_bits: u32,
    pub act_bits: u32,
    /// training loss per epoch (the "loss curve" record)
    pub loss_curve: Vec<f32>,
}

impl TrainedMlp {
    /// Quantized forward pass for one batch; returns logits `[n, classes]`.
    /// When `caches` is Some, stores per-layer (input, preact) for backprop.
    fn forward(
        &mut self,
        x: &[f32],
        n: usize,
        caches: Option<&mut Vec<(Vec<f32>, Vec<f32>)>>,
        train: bool,
    ) -> Vec<f32> {
        let mut cur = x.to_vec();
        let mut caches = caches;
        let nl = self.layers.len();
        for (li, layer) in self.layers.iter_mut().enumerate() {
            let wq = quantize_weights(&layer.w, self.weight_bits);
            let mut z = vec![0f32; n * layer.fout];
            crate::tensor::gemm(n, layer.fin, layer.fout, &cur, &wq.values, &mut z);
            for row in z.chunks_mut(layer.fout) {
                for (v, b) in row.iter_mut().zip(&layer.b) {
                    *v += b;
                }
            }
            // activation range is fixed at [-1, 1] (Brevitas QuantHardTanh
            // convention used by the FINN TFC/CNV models) — a dynamic EMA
            // range destabilizes low-bit training.
            let _ = (train, li, nl);
            if let Some(c) = caches.as_deref_mut() {
                c.push((cur.clone(), z.clone()));
            }
            cur = if layer.quantize_act {
                let s = act_scale_from_max(layer.act_max, self.act_bits);
                quantize_act(&z, s, self.act_bits)
            } else {
                z
            };
        }
        cur
    }

    /// Classification accuracy on a dataset (percent).
    pub fn accuracy(&mut self, data: &Dataset) -> f32 {
        let n = data.len();
        let logits = self.forward(&data.images, n, None, false);
        let classes = *self.dims.last().unwrap();
        let mut correct = 0usize;
        for i in 0..n {
            let row = &logits[i * classes..(i + 1) * classes];
            let pred = row
                .iter()
                .enumerate()
                .max_by(|a, b| a.1.partial_cmp(b.1).unwrap())
                .unwrap()
                .0;
            if pred == data.labels[i] {
                correct += 1;
            }
        }
        100.0 * correct as f32 / n as f32
    }

    /// Export as a QONNX TFC-style graph (batch-1).
    pub fn to_qonnx(&self, batch: usize) -> Result<crate::ir::ModelGraph> {
        let mut layers = Vec::new();
        for (li, layer) in self.layers.iter().enumerate() {
            let wq = quantize_weights(&layer.w, self.weight_bits);
            layers.push(DenseParams {
                w: crate::tensor::Tensor::new(vec![layer.fin, layer.fout], layer.w.clone()),
                bias: Some(crate::tensor::Tensor::new(vec![layer.fout], layer.b.clone())),
                w_scale: wq.scale,
                a_scale: if li + 1 < self.layers.len() {
                    Some(act_scale_from_max(layer.act_max, self.act_bits))
                } else {
                    None
                },
            });
        }
        let params = TfcParams { layers, weight_bits: self.weight_bits, act_bits: self.act_bits };
        tfc_batch(&params, batch)
    }
}

/// Train a QAT MLP on a dataset. The returned model carries the loss curve
/// (recorded per epoch) for EXPERIMENTS.md.
pub fn train_mlp(data: &Dataset, cfg: &QatConfig) -> Result<TrainedMlp> {
    ensure!(cfg.epochs >= 1 && cfg.batch >= 1);
    let mut dims = vec![data.dim];
    dims.extend_from_slice(&cfg.hidden);
    dims.push(data.classes);
    let mut rng = Rng::new(cfg.seed);
    let mut layers = Vec::new();
    for i in 0..dims.len() - 1 {
        let (fin, fout) = (dims[i], dims[i + 1]);
        layers.push(Layer {
            w: rng.he_weights(fin * fout, fin),
            vw: vec![0.0; fin * fout],
            b: vec![0.0; fout],
            vb: vec![0.0; fout],
            fin,
            fout,
            act_max: 1.0,
            quantize_act: i + 2 < dims.len(),
        });
    }
    let mut model = TrainedMlp {
        dims: dims.clone(),
        layers,
        weight_bits: cfg.weight_bits,
        act_bits: cfg.act_bits,
        loss_curve: Vec::new(),
    };

    let n = data.len();
    let classes = data.classes;
    for _epoch in 0..cfg.epochs {
        let perm = rng.permutation(n);
        let mut epoch_loss = 0f32;
        let mut batches = 0usize;
        for chunk in perm.chunks(cfg.batch) {
            let bs = chunk.len();
            // gather batch
            let mut x = Vec::with_capacity(bs * data.dim);
            for &i in chunk {
                x.extend_from_slice(data.image(i));
            }
            let mut caches: Vec<(Vec<f32>, Vec<f32>)> = Vec::new();
            let logits = model.forward(&x, bs, Some(&mut caches), true);

            // softmax CE loss + gradient
            let mut dlogits = vec![0f32; bs * classes];
            let mut loss = 0f32;
            for (bi, &i) in chunk.iter().enumerate() {
                let row = &logits[bi * classes..(bi + 1) * classes];
                let m = row.iter().copied().fold(f32::NEG_INFINITY, f32::max);
                let exps: Vec<f32> = row.iter().map(|v| (v - m).exp()).collect();
                let denom: f32 = exps.iter().sum();
                let label = data.labels[i];
                loss -= (exps[label] / denom).max(1e-12).ln();
                for c in 0..classes {
                    dlogits[bi * classes + c] =
                        (exps[c] / denom - if c == label { 1.0 } else { 0.0 }) / bs as f32;
                }
            }
            epoch_loss += loss / bs as f32;
            batches += 1;

            // backprop with STE
            let mut dout = dlogits;
            for li in (0..model.layers.len()).rev() {
                let quantize_act = model.layers[li].quantize_act;
                let act_max = model.layers[li].act_max;
                let (fin, fout) = (model.layers[li].fin, model.layers[li].fout);
                let (input, preact) = &caches[li];
                // activation STE: pass where |z| <= clip range. For sign
                // activations the window scales with the pre-activation
                // magnitude (the role BatchNorm plays in real BNNs) —
                // a unit window would mask nearly every gradient.
                let mut dz = dout;
                if quantize_act {
                    let clip = if cfg.act_bits == 1 {
                        let var = preact.iter().map(|v| v * v).sum::<f32>() / preact.len() as f32;
                        (2.0 * var.sqrt()).max(1.0)
                    } else {
                        let s = act_scale_from_max(act_max, cfg.act_bits);
                        let qmax = 2f32.powi(cfg.act_bits as i32 - 1) - 1.0;
                        s * qmax
                    };
                    for (g, &z) in dz.iter_mut().zip(preact.iter()) {
                        if z.abs() > clip {
                            *g = 0.0;
                        }
                    }
                }
                // dW = input^T · dz  (straight through the weight quantizer)
                let layer = &mut model.layers[li];
                let mut dw = vec![0f32; fin * fout];
                for b in 0..bs {
                    let xrow = &input[b * fin..(b + 1) * fin];
                    let grow = &dz[b * fout..(b + 1) * fout];
                    for (k, &xv) in xrow.iter().enumerate() {
                        if xv == 0.0 {
                            continue;
                        }
                        let drow = &mut dw[k * fout..(k + 1) * fout];
                        for (j, &gv) in grow.iter().enumerate() {
                            drow[j] += xv * gv;
                        }
                    }
                }
                // dx = dz · Wq^T
                let wq = quantize_weights(&layer.w, cfg.weight_bits);
                let mut dx = vec![0f32; bs * fin];
                for b in 0..bs {
                    let grow = &dz[b * fout..(b + 1) * fout];
                    let xgrad = &mut dx[b * fin..(b + 1) * fin];
                    for k in 0..fin {
                        let wrow = &wq.values[k * fout..(k + 1) * fout];
                        let mut acc = 0f32;
                        for (j, &gv) in grow.iter().enumerate() {
                            acc += gv * wrow[j];
                        }
                        xgrad[k] = acc;
                    }
                }
                // SGD + momentum, with latent weights clipped to [-1, 1]
                // (standard for binary/low-bit QAT)
                for (i, g) in dw.iter().enumerate() {
                    layer.vw[i] = cfg.momentum * layer.vw[i] - cfg.lr * g;
                    layer.w[i] = (layer.w[i] + layer.vw[i]).clamp(-1.0, 1.0);
                }
                // bias gradient: column sums of dz
                for j in 0..fout {
                    let mut g = 0f32;
                    for bi in 0..bs {
                        g += dz[bi * fout + j];
                    }
                    layer.vb[j] = cfg.momentum * layer.vb[j] - cfg.lr * g;
                    layer.b[j] += layer.vb[j];
                }
                dout = dx;
            }
        }
        model.loss_curve.push(epoch_loss / batches as f32);
    }
    Ok(model)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::zoo::synth_digits;

    fn quick_cfg(w: u32, a: u32) -> QatConfig {
        QatConfig { epochs: 8, ..QatConfig::tfc(w, a) }
    }

    #[test]
    fn loss_decreases() {
        let data = synth_digits(400, 11);
        let m = train_mlp(&data, &quick_cfg(2, 2)).unwrap();
        let first = m.loss_curve.first().unwrap();
        let last = m.loss_curve.last().unwrap();
        assert!(last < first, "loss did not decrease: {first} -> {last}");
    }

    #[test]
    fn w2a2_beats_chance_substantially() {
        let train = synth_digits(800, 21);
        let test = synth_digits(200, 22);
        let mut m = train_mlp(&train, &quick_cfg(2, 2)).unwrap();
        let acc = m.accuracy(&test);
        assert!(acc > 60.0, "w2a2 accuracy only {acc}%");
    }

    #[test]
    fn bipolar_w1a1_trains() {
        let train = synth_digits(800, 31);
        let test = synth_digits(200, 32);
        let mut m = train_mlp(&train, &quick_cfg(1, 1)).unwrap();
        let acc = m.accuracy(&test);
        assert!(acc > 30.0, "w1a1 accuracy only {acc}%");
    }

    #[test]
    fn exported_qonnx_matches_internal_accuracy() {
        use crate::exec::execute;
        let train = synth_digits(600, 41);
        let test = synth_digits(100, 42);
        let mut m = train_mlp(&train, &quick_cfg(2, 2)).unwrap();
        let internal_acc = m.accuracy(&test);

        let g = m.to_qonnx(test.len()).unwrap();
        let mut inputs = std::collections::BTreeMap::new();
        inputs.insert(
            "x".to_string(),
            crate::tensor::Tensor::new(vec![test.len(), 784], test.images.clone()),
        );
        let out = execute(&g, &inputs).unwrap();
        let logits = out.outputs.values().next().unwrap();
        let mut correct = 0usize;
        for i in 0..test.len() {
            let row = &logits.as_f32().unwrap()[i * 10..(i + 1) * 10];
            let pred = row.iter().enumerate().max_by(|a, b| a.1.partial_cmp(b.1).unwrap()).unwrap().0;
            if pred == test.labels[i] {
                correct += 1;
            }
        }
        let graph_acc = 100.0 * correct as f32 / test.len() as f32;
        // the QONNX export includes the 8-bit input quantizer the internal
        // forward pass lacks; allow a small gap
        assert!(
            (graph_acc - internal_acc).abs() <= 6.0,
            "internal {internal_acc}% vs exported-graph {graph_acc}%"
        );
    }
}

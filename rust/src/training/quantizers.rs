//! Training-time quantizers (the forward halves of the STE pairs).

/// A quantized weight tensor: values on the `scale`-spaced grid.
pub struct QuantizedWeights {
    pub values: Vec<f32>,
    pub scale: f32,
}

/// Quantize latent float weights for the forward pass.
///
/// * 1 bit — XNOR-Net style bipolar: `scale · sign(w)` with
///   `scale = mean |w|`.
/// * N bits — narrow symmetric: `scale = max |w| / (2^(N-1) - 1)`,
///   `q = clamp(round(w / scale))`.
pub fn quantize_weights(w: &[f32], bits: u32) -> QuantizedWeights {
    if bits == 1 {
        let scale = (w.iter().map(|v| v.abs()).sum::<f32>() / w.len() as f32).max(1e-8);
        QuantizedWeights {
            values: w.iter().map(|&v| if v >= 0.0 { scale } else { -scale }).collect(),
            scale,
        }
    } else if bits == 2 {
        // Ternary Weight Networks (Li & Liu): threshold Δ = 0.7·mean|w|,
        // scale = mean |w| over the supra-threshold weights. Max-scaled
        // ternary is unstable once any latent weight saturates.
        let mean_abs = w.iter().map(|v| v.abs()).sum::<f32>() / w.len() as f32;
        let delta = 0.7 * mean_abs;
        let (mut sum, mut cnt) = (0f32, 0usize);
        for &v in w {
            if v.abs() > delta {
                sum += v.abs();
                cnt += 1;
            }
        }
        let scale = if cnt > 0 { sum / cnt as f32 } else { mean_abs.max(1e-8) };
        QuantizedWeights {
            values: w
                .iter()
                .map(|&v| if v > delta { scale } else if v < -delta { -scale } else { 0.0 })
                .collect(),
            scale,
        }
    } else {
        let qmax = 2f32.powi(bits as i32 - 1) - 1.0;
        let maxabs = w.iter().fold(0f32, |m, v| m.max(v.abs())).max(1e-8);
        let scale = maxabs / qmax;
        QuantizedWeights {
            values: w
                .iter()
                .map(|&v| (v / scale).round().clamp(-qmax, qmax) * scale)
                .collect(),
            scale,
        }
    }
}

/// Activation scale from a calibrated max magnitude.
pub fn act_scale_from_max(act_max: f32, bits: u32) -> f32 {
    if bits == 1 {
        return 1.0; // bipolar: scale fixed at 1
    }
    let qmax = 2f32.powi(bits as i32 - 1) - 1.0;
    (act_max / qmax).max(1e-8)
}

/// Quantize pre-activations: sign for 1 bit, symmetric int-N otherwise.
pub fn quantize_act(z: &[f32], scale: f32, bits: u32) -> Vec<f32> {
    if bits == 1 {
        return z.iter().map(|&v| if v >= 0.0 { 1.0 } else { -1.0 }).collect();
    }
    let qmax = 2f32.powi(bits as i32 - 1) - 1.0;
    let qmin = -qmax - 1.0;
    z.iter()
        .map(|&v| (v / scale).round().clamp(qmin, qmax) * scale)
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bipolar_weights() {
        let q = quantize_weights(&[0.5, -0.1, 0.3, -0.9], 1);
        let alpha = (0.5 + 0.1 + 0.3 + 0.9) / 4.0;
        assert_eq!(q.scale, alpha);
        assert_eq!(q.values, vec![alpha, -alpha, alpha, -alpha]);
    }

    #[test]
    fn int2_weights_twn_grid() {
        // TWN: Δ = 0.7·mean|w| = 0.49; scale = mean of |w| > Δ = 0.8667
        let q = quantize_weights(&[1.0, -1.0, 0.2, 0.6], 2);
        let s = (1.0 + 1.0 + 0.6) / 3.0;
        assert!((q.scale - s).abs() < 1e-6);
        assert_eq!(q.values, vec![s, -s, 0.0, s]);
        // grid is ternary
        for v in &q.values {
            assert!(*v == 0.0 || v.abs() == s);
        }
    }

    #[test]
    fn int4_weights_on_grid() {
        let q = quantize_weights(&[0.7, -0.35, 0.05], 4);
        for v in &q.values {
            assert!((v / q.scale).fract().abs() < 1e-5);
        }
        assert_eq!(q.values[0], 0.7); // max maps exactly to qmax·s
    }

    #[test]
    fn act_quant_sign_and_grid() {
        assert_eq!(quantize_act(&[2.0, -0.5], 1.0, 1), vec![1.0, -1.0]);
        let out = quantize_act(&[0.9, -3.0], 0.25, 4);
        assert_eq!(out, vec![1.0, -2.0]); // clamped at -8·0.25
    }
}

//! Channels-first → channels-last conversion (paper §V, Fig. 3).
//!
//! FINN and hls4ml FPGA backends stream pixels with channels innermost, so
//! QONNX provides a transformation from ONNX's default NCHW to NHWC. The
//! strategy mirrors qonnx's:
//!
//! * every 4-D activation tensor becomes NHWC;
//! * shape-dependent ops (`Conv`, pools, `BatchNormalization`) get the
//!   `data_layout = "NHWC"` wrapper attribute so the graph remains
//!   executable for verification (weights stay OIHW);
//! * channel-broadcast parameter initializers of elementwise ops (shape
//!   `[C,1,1]`) are reshaped to `[C]` so they broadcast over the trailing
//!   channel axis;
//! * a `Transpose` back to NCHW is inserted in front of `Reshape`/
//!   `Flatten` so the flattened element order (and therefore downstream
//!   dense weights) is preserved;
//! * graph inputs/outputs with 4-D shapes are re-declared as NHWC.

use super::infer_shapes;
use crate::ir::{ModelGraph, Node};
use anyhow::{ensure, Result};
use std::collections::BTreeSet;

const LAYOUT_OPS: &[&str] = &[
    "Conv",
    "MaxPool",
    "AveragePool",
    "GlobalAveragePool",
    "BatchNormalization",
];

/// Elementwise ops that are layout-agnostic provided their secondary
/// inputs broadcast correctly.
const ELTWISE_OPS: &[&str] = &[
    "Relu", "Sign", "Sigmoid", "Tanh", "Add", "Sub", "Mul", "Div", "Quant", "BipolarQuant",
    "Trunc", "Clip", "QuantizeLinear", "DequantizeLinear", "MultiThreshold", "Identity", "Pad",
];

/// Convert a cleaned NCHW graph to channels-last. Requires shapes to be
/// inferred (run [`super::cleanup`] first).
pub fn to_channels_last(graph: &mut ModelGraph) -> Result<bool> {
    graph.sort_topologically()?;

    // set of tensors that are 4-D activations (to be relaid out)
    let mut nhwc: BTreeSet<String> = BTreeSet::new();
    for vi in &mut graph.inputs {
        if let Some(shape) = &vi.shape {
            if shape.len() == 4 {
                let (n, c, h, w) = (shape[0], shape[1], shape[2], shape[3]);
                vi.shape = Some(vec![n, h, w, c]);
                nhwc.insert(vi.name.clone());
            }
        }
    }
    if nhwc.is_empty() {
        return Ok(false); // nothing 4-D: dense-only model
    }

    let mut new_nodes: Vec<Node> = Vec::with_capacity(graph.nodes.len());
    let mut transpose_count = 0usize;
    for node in graph.nodes.clone() {
        let mut node = node;
        let op: &str = &node.op_type;
        if LAYOUT_OPS.contains(&op) {
            // data input 0 must be NHWC; params (weights etc.) untouched
            if nhwc.contains(&node.inputs[0]) {
                node.attrs.insert("data_layout".into(), "NHWC".into());
                for o in &node.outputs {
                    nhwc.insert(o.clone());
                }
            }
            new_nodes.push(node);
        } else if ELTWISE_OPS.contains(&op) {
            let data_is_nhwc = nhwc.contains(&node.inputs[0]);
            if data_is_nhwc {
                // fix channel-broadcast parameter initializers [C,1,1] -> [C]
                for inp in node.inputs.iter().skip(1) {
                    if let Some(t) = graph.initializers.get(inp) {
                        let s = t.shape().to_vec();
                        if s.len() == 3 && s[1] == 1 && s[2] == 1 && s[0] > 1 {
                            let flat = t.reshape(vec![s[0]])?;
                            graph.initializers.insert(inp.clone(), flat);
                        }
                    }
                }
                if op == "MultiThreshold" {
                    node.attrs.insert("data_layout".into(), "NHWC".into());
                }
                for o in &node.outputs {
                    nhwc.insert(o.clone());
                }
            }
            new_nodes.push(node);
        } else if matches!(op, "Reshape" | "Flatten") && nhwc.contains(&node.inputs[0]) {
            // preserve element order: transpose back to NCHW first
            let tname = graph.fresh_name(&format!("{}_nchw", node.inputs[0]));
            let tnode = Node::new("Transpose", &[&node.inputs[0]], &[&tname])
                .with_name(&format!("Transpose_cl_{transpose_count}"))
                .with_attr("perm", vec![0i64, 3, 1, 2]);
            transpose_count += 1;
            new_nodes.push(tnode);
            node.inputs[0] = tname;
            new_nodes.push(node);
        } else {
            ensure!(
                !node.present_inputs().any(|i| nhwc.contains(i)),
                "channels-last: op '{}' ({}) consumes an NHWC tensor but has no layout rule",
                node.name,
                node.op_type
            );
            new_nodes.push(node);
        }
    }
    graph.nodes = new_nodes;

    // re-declare 4-D outputs as NHWC
    for vi in &mut graph.outputs {
        if nhwc.contains(&vi.name) {
            if let Some(shape) = &vi.shape {
                if shape.len() == 4 {
                    let (n, c, h, w) = (shape[0], shape[1], shape[2], shape[3]);
                    vi.shape = Some(vec![n, h, w, c]);
                }
            }
        }
    }
    // stale intermediate shape annotations: drop and re-infer
    graph.value_info.clear();
    graph.sort_topologically()?;
    infer_shapes(graph)?;
    graph.validate()?;
    Ok(true)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::exec;
    use crate::ir::GraphBuilder;
    use crate::tensor::{nchw_to_nhwc, Tensor};
    use crate::transforms::cleanup;
    use std::collections::BTreeMap;

    /// conv -> relu -> quant -> maxpool -> flatten -> matmul
    fn small_cnn() -> ModelGraph {
        let mut b = GraphBuilder::new("cnn");
        b.input("x", vec![1, 3, 8, 8]);
        b.initializer("w", Tensor::new(vec![4, 3, 3, 3], (0..108).map(|v| (v % 7) as f32 - 3.0).collect()));
        b.node(
            "Conv",
            &["x", "w"],
            &["c"],
            &[("kernel_shape", vec![3i64, 3].into()), ("pads", vec![1i64, 1, 1, 1].into())],
        );
        b.node("Relu", &["c"], &["r"], &[]);
        b.quant("r", "q", 0.5, 0.0, 4.0, false, false, "ROUND");
        b.node("MaxPool", &["q"], &["p"], &[("kernel_shape", vec![2i64, 2].into())]);
        b.node("Flatten", &["p"], &["f"], &[]);
        b.initializer("w2", Tensor::new(vec![64, 2], (0..128).map(|v| (v % 5) as f32 - 2.0).collect()));
        b.node("MatMul", &["f", "w2"], &["y"], &[]);
        b.output_unknown("y");
        let mut g = b.finish().unwrap();
        cleanup(&mut g).unwrap();
        g
    }

    #[test]
    fn converts_and_preserves_semantics() {
        let g0 = small_cnn();
        let mut g1 = g0.clone();
        assert!(to_channels_last(&mut g1).unwrap());

        // input is now NHWC
        assert_eq!(g1.inputs[0].shape, Some(vec![1, 8, 8, 3]));
        // conv got the wrapper attribute
        let conv = g1.nodes.iter().find(|n| n.op_type == "Conv").unwrap();
        assert_eq!(conv.attr_str_or("data_layout", ""), "NHWC");
        // a transpose guards the flatten
        assert!(g1.nodes.iter().any(|n| n.op_type == "Transpose"));

        let x = Tensor::new(vec![1, 3, 8, 8], (0..192).map(|v| (v % 11) as f32 * 0.2 - 1.0).collect());
        let y0 = exec::execute_simple(&g0, &x).unwrap();
        let mut m = BTreeMap::new();
        m.insert("x".to_string(), nchw_to_nhwc(&x).unwrap());
        let y1 = exec::execute(&g1, &m).unwrap().outputs.into_values().next().unwrap();
        assert_eq!(y0, y1);
    }

    #[test]
    fn intermediate_shapes_are_nhwc() {
        // Fig. 3: "the 256 channels ... have now moved to the last position"
        let mut g = small_cnn();
        to_channels_last(&mut g).unwrap();
        assert_eq!(g.tensor_shape("c"), Some(vec![1, 8, 8, 4]));
        assert_eq!(g.tensor_shape("p"), Some(vec![1, 4, 4, 4]));
    }

    #[test]
    fn dense_only_model_untouched() {
        let mut b = GraphBuilder::new("dense");
        b.input("x", vec![1, 4]);
        b.node("Relu", &["x"], &["y"], &[]);
        b.output("y", vec![1, 4]);
        let mut g = b.finish().unwrap();
        assert!(!to_channels_last(&mut g).unwrap());
    }

    #[test]
    fn channelwise_scale_initializer_reshaped() {
        let mut b = GraphBuilder::new("cw");
        b.input("x", vec![1, 2, 2, 2]);
        b.quant_tensor_scale("x", "q", Tensor::new(vec![2, 1, 1], vec![0.5, 0.25]), 0.0, 4.0, true, false);
        b.output_unknown("q");
        let mut g = b.finish().unwrap();
        cleanup(&mut g).unwrap();
        let g0 = g.clone();
        to_channels_last(&mut g).unwrap();
        assert_eq!(g.initializers["q_scale"].shape(), &[2]);

        let x = Tensor::new(vec![1, 2, 2, 2], vec![0.9, -0.6, 0.3, 0.1, 0.9, -0.6, 0.3, 0.1]);
        let y0 = exec::execute_simple(&g0, &x).unwrap();
        let mut m = BTreeMap::new();
        m.insert("x".to_string(), nchw_to_nhwc(&x).unwrap());
        let y1 = exec::execute(&g, &m).unwrap().outputs.into_values().next().unwrap();
        assert_eq!(nchw_to_nhwc(&y0).unwrap(), y1);
    }
}

//! Cleanup pipeline: the paper's Fig. 1 → Fig. 2 step.
//!
//! `cleanup` = shape inference → constant folding → identity removal →
//! dead-node/dead-initializer elimination → unique node names →
//! topological order.

use super::{fold_constants, infer_shapes};
use crate::ir::ModelGraph;
use anyhow::Result;
use std::collections::BTreeSet;

/// Remove `Identity` and no-op `Dropout` nodes.
pub fn remove_identity(graph: &mut ModelGraph) -> Result<bool> {
    let mut changed = false;
    loop {
        let idx = graph
            .nodes
            .iter()
            .position(|n| matches!(n.op_type.as_str(), "Identity" | "Dropout"));
        match idx {
            Some(i) => {
                graph.remove_node_rewire(i)?;
                changed = true;
            }
            None => return Ok(changed),
        }
    }
}

/// Remove nodes whose outputs are never consumed, and initializers that
/// nothing references.
pub fn remove_dead_nodes(graph: &mut ModelGraph) -> Result<bool> {
    let mut changed = false;
    loop {
        let mut live: BTreeSet<String> = graph.outputs.iter().map(|o| o.name.clone()).collect();
        for n in &graph.nodes {
            for i in n.present_inputs() {
                live.insert(i.to_string());
            }
        }
        let dead = graph
            .nodes
            .iter()
            .position(|n| n.outputs.iter().all(|o| !live.contains(o)));
        match dead {
            Some(i) => {
                graph.nodes.remove(i);
                changed = true;
            }
            None => break,
        }
    }
    // dead initializers
    let mut referenced: BTreeSet<&str> = graph.outputs.iter().map(|o| o.name.as_str()).collect();
    for n in &graph.nodes {
        referenced.extend(n.present_inputs());
    }
    let before = graph.initializers.len();
    graph.initializers.retain(|k, _| referenced.contains(k.as_str()));
    changed |= graph.initializers.len() != before;
    // drop stale value_info entries
    let names = graph.all_tensor_names();
    graph.value_info.retain(|k, _| names.contains(k));
    Ok(changed)
}

/// Assign a unique, human-readable name to every node (`<OpType>_<i>`).
pub fn give_unique_names(graph: &mut ModelGraph) -> Result<bool> {
    let mut seen = BTreeSet::new();
    let mut changed = false;
    let mut counter = 0usize;
    for n in &mut graph.nodes {
        if n.name.is_empty() || !seen.insert(n.name.clone()) {
            loop {
                let cand = format!("{}_{counter}", n.op_type);
                counter += 1;
                if seen.insert(cand.clone()) {
                    n.name = cand;
                    changed = true;
                    break;
                }
            }
        }
    }
    Ok(changed)
}

/// The full cleaning pipeline (paper §V). Returns the cleaned node count.
pub fn cleanup(graph: &mut ModelGraph) -> Result<usize> {
    graph.sort_topologically()?;
    infer_shapes(graph)?;
    fold_constants(graph)?;
    remove_identity(graph)?;
    remove_dead_nodes(graph)?;
    give_unique_names(graph)?;
    graph.sort_topologically()?;
    // re-infer in case folding exposed new static shapes
    infer_shapes(graph)?;
    graph.validate()?;
    Ok(graph.nodes.len())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ir::{GraphBuilder, Node};
    use crate::tensor::Tensor;

    #[test]
    fn removes_identity_chain() {
        let mut b = GraphBuilder::new("idc");
        b.input("x", vec![2]);
        b.node("Identity", &["x"], &["a"], &[]);
        b.node("Identity", &["a"], &["c"], &[]);
        b.node("Relu", &["c"], &["y"], &[]);
        b.output("y", vec![2]);
        let mut g = b.finish().unwrap();
        cleanup(&mut g).unwrap();
        assert_eq!(g.nodes.len(), 1);
        assert_eq!(g.nodes[0].op_type, "Relu");
    }

    #[test]
    fn removes_dead_branches_and_inits() {
        let mut b = GraphBuilder::new("dead");
        b.input("x", vec![2]);
        b.initializer("unused", Tensor::zeros(vec![9]));
        b.node("Relu", &["x"], &["y"], &[]);
        b.node("Sigmoid", &["x"], &["never_used"], &[]);
        b.output("y", vec![2]);
        let mut g = b.finish().unwrap();
        cleanup(&mut g).unwrap();
        assert_eq!(g.nodes.len(), 1);
        assert!(!g.initializers.contains_key("unused"));
    }

    #[test]
    fn names_made_unique() {
        let mut g = ModelGraph::new("nm");
        g.inputs.push(crate::ir::ValueInfo::new("x", vec![1]));
        g.outputs.push(crate::ir::ValueInfo::new("y", vec![1]));
        g.nodes.push(Node::new("Relu", &["x"], &["a"])); // empty name
        g.nodes.push(Node::new("Relu", &["a"], &["y"])); // empty name
        give_unique_names(&mut g).unwrap();
        assert_ne!(g.nodes[0].name, g.nodes[1].name);
        assert!(!g.nodes[0].name.is_empty());
    }

    #[test]
    fn cleanup_preserves_semantics() {
        use crate::exec::execute_simple;
        let mut b = GraphBuilder::new("sem");
        b.input("x", vec![1, 4]);
        b.scalar("two", 2.0);
        b.scalar("three", 3.0);
        b.node("Mul", &["two", "three"], &["six"], &[]);
        b.node("Identity", &["x"], &["xi"], &[]);
        b.node("Mul", &["xi", "six"], &["y"], &[]);
        b.output("y", vec![1, 4]);
        let g0 = b.finish().unwrap();
        let mut g1 = g0.clone();
        cleanup(&mut g1).unwrap();
        let x = Tensor::new(vec![1, 4], vec![1.0, -2.0, 0.5, 3.0]);
        assert_eq!(
            execute_simple(&g0, &x).unwrap(),
            execute_simple(&g1, &x).unwrap()
        );
        assert!(g1.nodes.len() < g0.nodes.len());
    }
}

//! FINN ingestion (paper §VI-D): QONNX → FINN-ONNX dialect.
//!
//! The four steps from the paper:
//! 1. cleanup (caller runs [`super::cleanup`]);
//! 2. weight `Quant` nodes are *applied* to the float initializers and the
//!    quantization datatype stored as a tensor annotation;
//! 3. activation-path `Quant`/`BipolarQuant` nodes become FINN
//!    `MultiThreshold` nodes (absorbing a preceding `Relu`);
//! 4. special cases (e.g. average pooling via `Trunc`) are left intact —
//!    FINN handles them last; incompatible activations raise an error.

use super::quant_params_static;
use crate::datatypes::DataType;
use crate::ir::{ModelGraph, Node, DOMAIN_FINN};
use crate::ops::quant::{next_up, quant_bounds};
use crate::tensor::Tensor;
use anyhow::{bail, ensure, Result};

/// Step 2: fold `Quant`/`BipolarQuant` over initializers (weights/biases)
/// into quantized initializers with datatype annotations.
pub fn fold_weight_quants(graph: &mut ModelGraph) -> Result<bool> {
    let mut changed = false;
    loop {
        let Some(i) = graph.nodes.iter().position(|n| {
            matches!(n.op_type.as_str(), "Quant" | "BipolarQuant")
                && graph.initializers.contains_key(&n.inputs[0])
        }) else {
            if changed {
                super::remove_dead_nodes(graph)?;
                graph.sort_topologically()?;
            }
            return Ok(changed);
        };
        let node = graph.nodes[i].clone();
        let ins: Vec<&Tensor> = node
            .present_inputs()
            .map(|t| graph.initializers.get(t).expect("quant params must be static"))
            .collect();
        let out = crate::ops::execute_node(&node, &ins)?.remove(0);
        let dt = if node.op_type == "BipolarQuant" {
            DataType::Bipolar
        } else {
            let p = quant_params_static(graph, &node)?;
            DataType::from_quant_params(p.signed, p.narrow, p.bit_width)
        };
        let out_name = node.outputs[0].clone();
        graph.initializers.insert(out_name.clone(), out);
        graph.set_tensor_datatype(&out_name, dt);
        graph.nodes.remove(i);
        changed = true;
    }
}

/// Compute the `MultiThreshold` equivalent of a static `Quant`:
/// thresholds `t_i = s (q_min - z + i - 1/2)` (ROUND) or
/// `t_i = s (q_min - z + i)` (FLOOR), `out_scale = s`,
/// `out_bias = s (q_min - z)`.
///
/// ROUND is round-half-to-even while `MultiThreshold` counts with `>=`; at
/// the boundary into level `m = q_min + i`, a tie (`x/s + z = m - 1/2`)
/// rounds *up* only when `m` is odd. For even `m` the threshold is nudged
/// one ULP upward so the exact tie stays below it — making the conversion
/// bit-exact, not approximate.
pub fn quant_to_thresholds(
    scale: &[f64],
    zero_point: f64,
    bit_width: f64,
    signed: bool,
    narrow: bool,
    rounding_mode: &str,
) -> Result<(Tensor, f32, f32)> {
    let (qmin, qmax) = quant_bounds(signed, narrow, bit_width);
    let steps = (qmax - qmin) as usize;
    ensure!(steps >= 1, "degenerate quantizer with no thresholds");
    let offset = match rounding_mode {
        "ROUND" => 0.5,
        "FLOOR" => 0.0,
        other => bail!("FINN ingestion supports ROUND/FLOOR rounding, got '{other}'"),
    };
    let channels = scale.len();
    let mut th = Vec::with_capacity(channels * steps);
    for &s in scale {
        for i in 1..=steps {
            let mut t = (s * (qmin - zero_point + i as f64 - offset)) as f32;
            if rounding_mode == "ROUND" {
                // At the tie x/s + z = m - 1/2 (m = qmin + i, the level
                // entered at t), half-even picks the even of {m-1, m}:
                // even m enters the level (tie included), odd m stays
                // below (tie excluded -> nudge threshold up one ULP).
                // The parity is m's — the value being rounded is x/s + z,
                // so the zero point shifts the threshold but not which
                // integer the tie resolves to.
                let m = qmin + i as f64;
                if m.rem_euclid(2.0) != 0.0 {
                    t = next_up(t);
                }
            }
            th.push(t);
        }
    }
    ensure!(
        (scale.windows(2).all(|w| w[0] == w[1])),
        "per-channel out_scale requires uniform scale; use channel thresholds with shared scale"
    );
    let s0 = scale[0];
    Ok((
        Tensor::new(vec![channels, steps], th),
        s0 as f32,
        (s0 * (qmin - zero_point)) as f32,
    ))
}

/// Step 3: convert activation-path `Quant`/`BipolarQuant` nodes into
/// `MultiThreshold`, absorbing a preceding `Relu` when its effect is
/// subsumed by the thresholds.
pub fn quant_to_multithreshold(graph: &mut ModelGraph) -> Result<bool> {
    // FINN supports ReLU / hardtanh (Clip) / identity activations only.
    for n in &graph.nodes {
        if matches!(n.op_type.as_str(), "Sigmoid" | "Tanh" | "Softmax") {
            let feeds_quant = graph
                .consumers(&n.outputs[0])
                .iter()
                .any(|&c| matches!(graph.nodes[c].op_type.as_str(), "Quant" | "BipolarQuant"));
            if feeds_quant {
                bail!(
                    "FINN ingestion: unsupported activation '{}' ({}) in the quantized \
                     activation path (FINN supports relu, hardtanh, identity)",
                    n.name,
                    n.op_type
                );
            }
        }
    }
    let mut changed = false;
    'outer: loop {
        graph.sort_topologically()?;
        for i in 0..graph.nodes.len() {
            let node = graph.nodes[i].clone();
            let (th, out_scale, out_bias) = match node.op_type.as_str() {
                "Quant" => {
                    let scale_t = graph
                        .initializer(&node.inputs[1])
                        .ok_or_else(|| anyhow::anyhow!("dynamic scale unsupported by FINN ingest"))?;
                    let zp = graph.initializer(&node.inputs[2]).unwrap().scalar_value()?;
                    let bw = graph.initializer(&node.inputs[3]).unwrap().scalar_value()?;
                    let signed = node.attr_int_or("signed", 1) != 0;
                    let narrow = node.attr_int_or("narrow", 0) != 0;
                    let mode = node.attr_str_or("rounding_mode", "ROUND");
                    quant_to_thresholds(&scale_t.to_f64_vec(), f64::from(zp), f64::from(bw), signed, narrow, &mode)?
                }
                "BipolarQuant" => {
                    let s = graph.initializer(&node.inputs[1]).unwrap().scalar_value()?;
                    // y = s * sign(x) = 2s * count(x >= 0) - s
                    (Tensor::new(vec![1, 1], vec![0.0]), 2.0 * s, -s)
                }
                _ => continue,
            };
            // absorb preceding Relu when thresholds are all positive
            let mut src = node.inputs[0].clone();
            if let Some(p) = graph.producer(&src) {
                if graph.nodes[p].op_type == "Relu"
                    && graph.consumers(&graph.nodes[p].outputs[0]).len() == 1
                    && th.min_value()? >= 0.0
                    && out_bias >= 0.0
                {
                    src = graph.nodes[p].inputs[0].clone();
                    let pi = p;
                    graph.nodes.remove(pi);
                }
            }
            // re-locate the quant node (indices shifted if relu removed)
            let qi = graph.nodes.iter().position(|n| n.name == node.name).unwrap();
            let th_name = graph.fresh_name(&format!("{}_thresh", node.outputs[0]));
            graph.initializers.insert(th_name.clone(), th);
            let dt = if node.op_type == "BipolarQuant" {
                DataType::Bipolar
            } else {
                let p = quant_params_static(graph, &node).ok();
                p.map(|p| DataType::from_quant_params(p.signed, p.narrow, p.bit_width))
                    .unwrap_or(DataType::Float32)
            };
            let mt = Node::new("MultiThreshold", &[&src, &th_name], &[&node.outputs[0]])
                .with_domain(DOMAIN_FINN)
                .with_name(&format!("{}_mt", node.name))
                .with_attr("out_scale", out_scale)
                .with_attr("out_bias", out_bias);
            graph.set_tensor_datatype(&node.outputs[0], dt);
            graph.nodes[qi] = mt;
            super::remove_dead_nodes(graph)?;
            changed = true;
            continue 'outer;
        }
        if changed {
            graph.sort_topologically()?;
            graph.validate()?;
        }
        return Ok(changed);
    }
}

/// The full FINN ingestion flow (steps 2–3; step 1 is [`super::cleanup`],
/// step 4 — avg-pool special cases — keeps `Trunc` nodes as-is).
pub fn convert_to_finn(graph: &mut ModelGraph) -> Result<bool> {
    let a = fold_weight_quants(graph)?;
    let b = quant_to_multithreshold(graph)?;
    Ok(a || b)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::exec::execute_simple;
    use crate::ir::GraphBuilder;
    use crate::transforms::cleanup;

    fn close(a: &[f32], b: &[f32]) {
        for (x, y) in a.iter().zip(b) {
            assert!((x - y).abs() < 1e-5, "{x} vs {y}");
        }
    }

    #[test]
    fn thresholds_uint2_relu() {
        let (th, os, ob) = quant_to_thresholds(&[1.0], 0.0, 2.0, false, false, "ROUND").unwrap();
        assert_eq!(th.shape(), &[1, 3]);
        // odd levels (1, 3) carry a one-ULP tie nudge
        close(th.as_f32().unwrap(), &[0.5, 1.5, 2.5]);
        assert!(th.as_f32().unwrap()[0] > 0.5 && th.as_f32().unwrap()[1] == 1.5);
        assert_eq!((os, ob), (1.0, 0.0));
    }

    #[test]
    fn thresholds_int3_symmetric() {
        let (th, os, ob) = quant_to_thresholds(&[0.5], 0.0, 3.0, true, false, "ROUND").unwrap();
        assert_eq!(th.shape(), &[1, 7]);
        close(th.as_f32().unwrap(), &[-1.75, -1.25, -0.75, -0.25, 0.25, 0.75, 1.25]);
        assert_eq!((os, ob), (0.5, -2.0));
    }

    #[test]
    fn thresholds_exact_at_ties() {
        // bit-exact tie behavior: half-even rounds 0.5 -> 0 (stays below
        // level 1) but 1.5 -> 2 (enters level 2)
        use crate::ops::multithreshold::multi_threshold;
        let (th, os, ob) = quant_to_thresholds(&[1.0], 0.0, 4.0, false, false, "ROUND").unwrap();
        let node = crate::ir::Node::new("MultiThreshold", &["x", "t"], &["y"])
            .with_attr("out_scale", os)
            .with_attr("out_bias", ob);
        let x = Tensor::new(vec![1, 4], vec![0.5, 1.5, 2.5, 3.5]);
        let y = multi_threshold(&node, &[&x, &th]).unwrap();
        assert_eq!(y[0].as_f32().unwrap(), &[0.0, 2.0, 2.0, 4.0]);
    }

    #[test]
    fn thresholds_exact_at_ties_with_odd_zero_point() {
        // z = 1: x = -0.5 gives x/s + z = 0.5, which half-even rounds to
        // 0 — level 1 must NOT be entered at the tie (level parity, not
        // level-minus-z parity, decides).
        use crate::ops::multithreshold::multi_threshold;
        let (th, os, ob) = quant_to_thresholds(&[1.0], 1.0, 2.0, false, false, "ROUND").unwrap();
        let node = crate::ir::Node::new("MultiThreshold", &["x", "t"], &["y"])
            .with_attr("out_scale", os)
            .with_attr("out_bias", ob);
        let x = Tensor::new(vec![1, 4], vec![-0.5, 0.5, 1.5, 2.5]);
        let got = multi_threshold(&node, &[&x, &th]).unwrap();
        let quant = crate::ir::Node::new("Quant", &["x", "s", "z", "b"], &["y"])
            .with_attr("signed", false)
            .with_attr("rounding_mode", "ROUND");
        let want = crate::ops::quant::quant_op(
            &quant,
            &[&x, &Tensor::scalar(1.0), &Tensor::scalar(1.0), &Tensor::scalar(2.0)],
        )
        .unwrap();
        assert_eq!(got[0], want[0]);
    }

    fn relu_quant_graph(signed: bool) -> ModelGraph {
        let mut b = GraphBuilder::new("rq");
        b.input("x", vec![1, 8]);
        b.node("Relu", &["x"], &["r"], &[]);
        b.quant("r", "y", 0.5, 0.0, 3.0, signed, false, "ROUND");
        b.output("y", vec![1, 8]);
        b.finish().unwrap()
    }

    #[test]
    fn relu_quant_becomes_single_multithreshold() {
        let g0 = relu_quant_graph(false);
        let mut g1 = g0.clone();
        assert!(quant_to_multithreshold(&mut g1).unwrap());
        let h = g1.op_histogram();
        assert_eq!(h.get("MultiThreshold"), Some(&1));
        assert!(!h.contains_key("Relu"), "Relu should be absorbed");
        assert!(!h.contains_key("Quant"));

        // integer-grid inputs (like real accumulators): exact equivalence
        let x = Tensor::new(vec![1, 8], vec![-3.0, -1.0, 0.0, 0.2, 0.3, 1.0, 2.0, 99.0]);
        assert_eq!(execute_simple(&g0, &x).unwrap(), execute_simple(&g1, &x).unwrap());
        assert_eq!(g1.tensor_datatype("y"), DataType::Uint(3));
    }

    #[test]
    fn signed_identity_quant_keeps_negative_range() {
        let mut b = GraphBuilder::new("sq");
        b.input("x", vec![1, 6]);
        b.quant("x", "y", 1.0, 0.0, 3.0, true, false, "ROUND");
        b.output("y", vec![1, 6]);
        let g0 = b.finish().unwrap();
        let mut g1 = g0.clone();
        quant_to_multithreshold(&mut g1).unwrap();
        let x = Tensor::new(vec![1, 6], vec![-99.0, -2.2, -0.8, 0.3, 2.2, 99.0]);
        assert_eq!(execute_simple(&g0, &x).unwrap(), execute_simple(&g1, &x).unwrap());
    }

    #[test]
    fn bipolar_becomes_sign_threshold() {
        let mut b = GraphBuilder::new("bp");
        b.input("x", vec![1, 4]);
        b.bipolar_quant("x", "y", 0.5);
        b.output("y", vec![1, 4]);
        let g0 = b.finish().unwrap();
        let mut g1 = g0.clone();
        quant_to_multithreshold(&mut g1).unwrap();
        let x = Tensor::new(vec![1, 4], vec![-7.0, -0.1, 0.1, 7.0]);
        assert_eq!(execute_simple(&g0, &x).unwrap(), execute_simple(&g1, &x).unwrap());
        assert_eq!(g1.tensor_datatype("y"), DataType::Bipolar);
    }

    #[test]
    fn weight_quants_folded_with_annotation() {
        let mut b = GraphBuilder::new("w");
        b.input("x", vec![1, 2]);
        b.initializer("w", Tensor::new(vec![2, 2], vec![0.6, -0.4, 1.9, 0.04]));
        b.quant("w", "wq", 0.5, 0.0, 4.0, true, false, "ROUND");
        b.node("MatMul", &["x", "wq"], &["y"], &[]);
        b.output("y", vec![1, 2]);
        let g0 = b.finish().unwrap();
        let mut g1 = g0.clone();
        assert!(fold_weight_quants(&mut g1).unwrap());
        assert!(!g1.op_histogram().contains_key("Quant"));
        assert_eq!(g1.initializers["wq"].as_f32().unwrap(), &[0.5, -0.5, 2.0, 0.0]);
        assert_eq!(g1.tensor_datatype("wq"), DataType::Int(4));
        let x = Tensor::new(vec![1, 2], vec![1.0, 2.0]);
        assert_eq!(execute_simple(&g0, &x).unwrap(), execute_simple(&g1, &x).unwrap());
    }

    #[test]
    fn rejects_sigmoid_activation_path() {
        let mut b = GraphBuilder::new("sig");
        b.input("x", vec![1, 4]);
        b.node("Sigmoid", &["x"], &["s"], &[]);
        b.quant("s", "y", 0.5, 0.0, 4.0, false, false, "ROUND");
        b.output("y", vec![1, 4]);
        let mut g = b.finish().unwrap();
        let err = quant_to_multithreshold(&mut g).unwrap_err();
        assert!(err.to_string().contains("unsupported activation"));
    }

    #[test]
    fn full_flow_on_mixed_graph() {
        let mut b = GraphBuilder::new("full");
        b.input("x", vec![1, 4]);
        b.quant("x", "xq", 1.0, 0.0, 4.0, true, false, "ROUND");
        b.initializer("w", Tensor::new(vec![4, 3], (0..12).map(|v| (v as f32 - 6.0) * 0.3).collect()));
        b.quant("w", "wq", 0.25, 0.0, 3.0, true, true, "ROUND");
        b.node("MatMul", &["xq", "wq"], &["mm"], &[]);
        b.node("Relu", &["mm"], &["r"], &[]);
        b.quant("r", "y", 1.0, 0.0, 4.0, false, false, "ROUND");
        b.output("y", vec![1, 3]);
        let g0 = b.finish().unwrap();
        let mut g1 = g0.clone();
        cleanup(&mut g1).unwrap();
        convert_to_finn(&mut g1).unwrap();
        let h = g1.op_histogram();
        assert_eq!(h.get("MultiThreshold"), Some(&2));
        assert!(!h.contains_key("Quant"));
        let x = Tensor::new(vec![1, 4], vec![2.0, -1.0, 3.0, 0.0]);
        assert_eq!(execute_simple(&g0, &x).unwrap(), execute_simple(&g1, &x).unwrap());
    }
}

//! Constant folding (paper §V: "basic graph optimizations, such as
//! constant folding").
//!
//! A node folds when every input is a constant (initializer or previously
//! folded). `Shape` additionally folds whenever its input's *shape* is
//! statically known — that is what collapses the exporter's
//! `Shape→Gather→Unsqueeze→Concat→Reshape` chain (Fig. 1 → Fig. 2).

use crate::ir::ModelGraph;
use crate::ops;
use crate::tensor::Tensor;
use anyhow::{Context, Result};

/// Fold all constant subgraphs into initializers. Returns true if the
/// graph changed. Run [`super::infer_shapes`] first so `Shape` nodes fold.
///
/// `Quant`/`BipolarQuant`/`Trunc` nodes are *excluded* even when their
/// inputs are constant — same as qonnx's `FoldConstants`: weight
/// quantizers carry the precision information the backends and metrics
/// need, and only dedicated ingestion passes may fold them
/// ([`super::convert_to_finn`], [`super::hls4ml_ingest`]).
pub fn fold_constants(graph: &mut ModelGraph) -> Result<bool> {
    let mut changed_any = false;
    loop {
        let mut folded = None;
        for (i, node) in graph.nodes.iter().enumerate() {
            let foldable = match node.op_type.as_str() {
                // quantizers are never folded (see docs above)
                "Quant" | "BipolarQuant" | "Trunc" => false,
                // Constant is always foldable
                "Constant" => true,
                // Shape folds off static shape info even for runtime tensors
                "Shape" => graph.tensor_shape(&node.inputs[0]).is_some(),
                _ => node.present_inputs().all(|t| graph.initializers.contains_key(t)),
            };
            if !foldable || node.outputs.iter().any(|o| graph.is_output(o)) {
                continue;
            }
            folded = Some(i);
            break;
        }
        let Some(i) = folded else {
            return Ok(changed_any);
        };
        let node = graph.nodes[i].clone();
        let outs = if node.op_type == "Shape" {
            let shape = graph.tensor_shape(&node.inputs[0]).unwrap();
            let s: Vec<i64> = shape.iter().map(|&d| d as i64).collect();
            let n = s.len();
            vec![Tensor::new_i64(vec![n], s)]
        } else {
            let ins: Vec<&Tensor> = node
                .present_inputs()
                .map(|t| graph.initializers.get(t).unwrap())
                .collect();
            ops::execute_node(&node, &ins)
                .with_context(|| format!("folding node '{}' ({})", node.name, node.op_type))?
        };
        for (name, t) in node.outputs.iter().zip(outs) {
            graph.initializers.insert(name.clone(), t);
        }
        graph.nodes.remove(i);
        changed_any = true;
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ir::{AttrValue, GraphBuilder, Node};
    use crate::transforms::infer_shapes;

    #[test]
    fn folds_constant_arithmetic() {
        let mut b = GraphBuilder::new("f");
        b.input("x", vec![2]);
        b.scalar("a", 2.0);
        b.scalar("c", 3.0);
        b.node("Mul", &["a", "c"], &["ac"], &[]);
        b.node("Add", &["x", "ac"], &["y"], &[]);
        b.output("y", vec![2]);
        let mut g = b.finish().unwrap();
        assert!(fold_constants(&mut g).unwrap());
        assert_eq!(g.nodes.len(), 1);
        assert_eq!(g.initializers["ac"].scalar_value().unwrap(), 6.0);
    }

    #[test]
    fn folds_exporter_flatten_chain() {
        // the Fig. 1 Shape/Gather/Unsqueeze/Concat/Reshape structure
        let mut b = GraphBuilder::new("chain");
        b.input("x", vec![2, 3, 2, 2]);
        b.initializer("idx", Tensor::new_i64(vec![], vec![0]));
        b.initializer("minus1", Tensor::new_i64(vec![1], vec![-1]));
        b.node("Shape", &["x"], &["s"], &[]);
        b.node("Gather", &["s", "idx"], &["g"], &[("axis", AttrValue::Int(0))]);
        b.node("Unsqueeze", &["g"], &["u"], &[("axes", AttrValue::Ints(vec![0]))]);
        b.node("Concat", &["u", "minus1"], &["target"], &[("axis", AttrValue::Int(0))]);
        b.node("Reshape", &["x", "target"], &["y"], &[]);
        b.output_unknown("y");
        let mut g = b.finish().unwrap();
        infer_shapes(&mut g).unwrap();
        assert!(fold_constants(&mut g).unwrap());
        // only the Reshape survives, with a constant target
        assert_eq!(g.nodes.len(), 1);
        assert_eq!(g.nodes[0].op_type, "Reshape");
        assert_eq!(g.initializers["target"].as_i64().unwrap(), &[2, -1]);
        g.validate().unwrap();
    }

    #[test]
    fn does_not_fold_graph_outputs() {
        let mut g = ModelGraph::new("o");
        g.outputs.push(crate::ir::ValueInfo::new("y", vec![1]));
        g.nodes.push(
            Node::new("Constant", &[], &["y"])
                .with_name("c")
                .with_attr("value", Tensor::scalar(1.0)),
        );
        assert!(!fold_constants(&mut g).unwrap());
        assert_eq!(g.nodes.len(), 1);
    }
}

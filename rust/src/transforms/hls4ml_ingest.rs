//! hls4ml-style ingestion (paper §VI-C).
//!
//! hls4ml distinguishes quantization of *constants* (weights/biases — apply
//! in place, keep integer values, append a dequantize node when the scale
//! is non-unitary) from quantization of the *data flow* (kept as explicit
//! quantize ops). The dequantize (scale) nodes are then propagated down
//! across linear operators so the expensive math runs on integers, and
//! adjacent scale multiplications are merged. Scales may not cross
//! nonlinear activations or quantized nodes.

use super::quant_params_static;
use crate::datatypes::DataType;
use crate::ir::{ModelGraph, Node};
use crate::ops::quant::{quant_bounds, round_half_even, RoundingMode};
use crate::tensor::Tensor;
use anyhow::{ensure, Result};

/// Quantize constant paths: `Quant(W_init)` becomes an integer-valued
/// initializer plus a `Mul(scale)` dequantize node (skipped when the scale
/// is unitary).
pub fn quantize_constant_paths(graph: &mut ModelGraph) -> Result<bool> {
    let mut changed = false;
    loop {
        let Some(i) = graph.nodes.iter().position(|n| {
            n.op_type == "Quant" && graph.initializers.contains_key(&n.inputs[0])
        }) else {
            if changed {
                super::remove_dead_nodes(graph)?;
                graph.sort_topologically()?;
            }
            return Ok(changed);
        };
        let node = graph.nodes[i].clone();
        let p = quant_params_static(graph, &node)?;
        ensure!(
            p.zero_point == 0.0,
            "hls4ml constant quantization with nonzero offset not supported (node '{}')",
            node.name
        );
        let mode = RoundingMode::from_str(&p.rounding_mode)?;
        let (lo, hi) = quant_bounds(p.signed, p.narrow, p.bit_width);
        let w = graph.initializers[&node.inputs[0]].clone();
        // integer-grid constant (NOT dequantized — hls4ml keeps integers)
        let w_int = w.map(|v| mode.apply(f64::from(v) / f64::from(p.scale)).clamp(lo, hi) as f32)?;
        let _ = round_half_even; // (RoundingMode::Round uses it internally)

        let out = node.outputs[0].clone();
        graph.nodes.remove(i);
        if p.scale == 1.0 {
            graph.initializers.insert(out.clone(), w_int);
            graph.set_tensor_datatype(&out, DataType::from_quant_params(p.signed, p.narrow, p.bit_width));
        } else {
            let int_name = graph.fresh_name(&format!("{out}_int"));
            let scale_name = graph.fresh_name(&format!("{out}_descale"));
            graph.initializers.insert(int_name.clone(), w_int);
            graph.initializers.insert(scale_name.clone(), Tensor::scalar(p.scale));
            graph.set_tensor_datatype(&int_name, DataType::from_quant_params(p.signed, p.narrow, p.bit_width));
            let mul = Node::new("Mul", &[&int_name, &scale_name], &[&out])
                .with_name(&format!("{}_dequant", node.name));
            graph.nodes.push(mul);
        }
        changed = true;
    }
}

/// True if `node` is a `Mul` by a constant scale tensor; returns the scale
/// input index.
fn const_scale_input(graph: &ModelGraph, node: &Node) -> Option<usize> {
    if node.op_type != "Mul" {
        return None;
    }
    // prefer a scalar constant (both inputs can be initializers when the
    // dequantized constant is an integer weight tensor times a scale)
    for (i, inp) in node.inputs.iter().enumerate() {
        if graph.initializers.get(inp).is_some_and(|t| t.numel() == 1) {
            return Some(i);
        }
    }
    for (i, inp) in node.inputs.iter().enumerate() {
        if graph.initializers.contains_key(inp) {
            return Some(i);
        }
    }
    None
}

/// Propagate dequantize `Mul(scale)` nodes downward across `MatMul`/`Conv`
/// (linear, so the scale commutes) and merge chained scale `Mul`s. Scales
/// do not cross nonlinear activations or `Quant`/`MultiThreshold` nodes.
pub fn propagate_dequant(graph: &mut ModelGraph) -> Result<bool> {
    let mut changed = false;
    'outer: loop {
        graph.sort_topologically()?;
        for mi in 0..graph.nodes.len() {
            let mul = graph.nodes[mi].clone();
            let Some(scale_idx) = const_scale_input(graph, &mul) else { continue };
            let scale_name = mul.inputs[scale_idx].clone();
            let data_name = mul.inputs[1 - scale_idx].clone();
            let out = mul.outputs[0].clone();
            if graph.is_output(&out) {
                continue;
            }
            let consumers = graph.consumers(&out);
            if consumers.len() != 1 {
                continue;
            }
            let ci = consumers[0];
            let cons = graph.nodes[ci].clone();
            let scale_t = graph.initializers[&scale_name].clone();
            match cons.op_type.as_str() {
                // linear ops: move the scale below (scalar scales always
                // commute; per-channel handled for the weight operand)
                "MatMul" | "Conv" | "Gemm" if scale_t.numel() == 1 => {
                    let new_out = graph.fresh_name(&format!("{}_noscale", cons.outputs[0]));
                    let cons_out = cons.outputs[0].clone();
                    // bias does not commute with a scale on an input
                    if cons.op_type != "MatMul" && cons.inputs.len() > 2 && !cons.inputs[2].is_empty() {
                        continue;
                    }
                    let which = cons.inputs.iter().position(|x| *x == out).unwrap();
                    let mut new_cons = cons.clone();
                    new_cons.inputs[which] = data_name.clone();
                    new_cons.outputs[0] = new_out.clone();
                    let new_mul = Node::new("Mul", &[&new_out, &scale_name], &[&cons_out])
                        .with_name(&format!("{}_pushed", mul.name));
                    // remove old mul + old consumer, add new pair
                    let mut rm = vec![mi, ci];
                    rm.sort_unstable();
                    for i in rm.into_iter().rev() {
                        graph.nodes.remove(i);
                    }
                    graph.nodes.push(new_cons);
                    graph.nodes.push(new_mul);
                    changed = true;
                    continue 'outer;
                }
                // merge Mul(Mul(x, a), b) -> Mul(x, a*b)
                "Mul" => {
                    if let Some(s2_idx) = const_scale_input(graph, &cons) {
                        let s2_name = cons.inputs[s2_idx].clone();
                        let s2 = graph.initializers[&s2_name].clone();
                        let merged = scale_t.binary_op(&s2, |a, b| a * b)?;
                        let merged_name = graph.fresh_name(&format!("{}_merged_scale", cons.name));
                        graph.initializers.insert(merged_name.clone(), merged);
                        let cons_out = cons.outputs[0].clone();
                        let new_mul = Node::new("Mul", &[&data_name, &merged_name], &[&cons_out])
                            .with_name(&format!("{}_merged", cons.name));
                        let mut rm = vec![mi, ci];
                        rm.sort_unstable();
                        for i in rm.into_iter().rev() {
                            graph.nodes.remove(i);
                        }
                        graph.nodes.push(new_mul);
                        changed = true;
                        continue 'outer;
                    }
                }
                _ => {}
            }
        }
        if changed {
            super::remove_dead_nodes(graph)?;
            graph.sort_topologically()?;
            graph.validate()?;
        }
        return Ok(changed);
    }
}

/// Full hls4ml-style ingestion: constant quantization then dequant
/// propagation to fixpoint.
pub fn hls4ml_ingest(graph: &mut ModelGraph) -> Result<bool> {
    let a = quantize_constant_paths(graph)?;
    let b = propagate_dequant(graph)?;
    Ok(a || b)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::exec::execute_simple;
    use crate::ir::GraphBuilder;

    fn wq_matmul() -> ModelGraph {
        let mut b = GraphBuilder::new("wq");
        b.input("x", vec![1, 4]);
        b.initializer("w", Tensor::new(vec![4, 2], vec![0.6, -0.4, 0.3, 0.1, -0.2, 0.5, 0.05, -0.7]));
        b.quant("w", "wq", 0.25, 0.0, 4.0, true, false, "ROUND");
        b.node("MatMul", &["x", "wq"], &["y"], &[]);
        b.output("y", vec![1, 2]);
        b.finish().unwrap()
    }

    #[test]
    fn constants_become_integers_with_descale() {
        let g0 = wq_matmul();
        let mut g1 = g0.clone();
        assert!(quantize_constant_paths(&mut g1).unwrap());
        // integer weights
        let int_name = g1
            .initializers
            .keys()
            .find(|k| k.contains("_int"))
            .expect("integer weight initializer")
            .clone();
        assert!(g1.initializers[&int_name].as_f32().unwrap().iter().all(|v| v.fract() == 0.0));
        assert_eq!(g1.tensor_datatype(&int_name), DataType::Int(4));
        // semantics preserved (Mul(scale) reassociation is exact here)
        let x = Tensor::new(vec![1, 4], vec![1.0, 2.0, -1.0, 0.5]);
        let y0 = execute_simple(&g0, &x).unwrap();
        let y1 = execute_simple(&g1, &x).unwrap();
        for (a, b) in y0.as_f32().unwrap().iter().zip(y1.as_f32().unwrap()) {
            assert!((a - b).abs() < 1e-6);
        }
    }

    #[test]
    fn unit_scale_needs_no_descale_node() {
        let mut b = GraphBuilder::new("u");
        b.input("x", vec![1, 2]);
        b.initializer("w", Tensor::new(vec![2, 2], vec![1.2, -0.7, 3.9, 0.4]));
        b.quant("w", "wq", 1.0, 0.0, 4.0, true, false, "ROUND");
        b.node("MatMul", &["x", "wq"], &["y"], &[]);
        b.output("y", vec![1, 2]);
        let mut g = b.finish().unwrap();
        quantize_constant_paths(&mut g).unwrap();
        assert!(!g.op_histogram().contains_key("Mul"));
        assert_eq!(g.initializers["wq"].as_f32().unwrap(), &[1.0, -1.0, 4.0, 0.0]);
    }

    #[test]
    fn dequant_propagates_below_matmul() {
        let g0 = wq_matmul();
        let mut g1 = g0.clone();
        hls4ml_ingest(&mut g1).unwrap();
        // graph order must now be MatMul(int) -> Mul(scale)
        let order: Vec<&str> = g1.nodes.iter().map(|n| n.op_type.as_str()).collect();
        assert_eq!(order, vec!["MatMul", "Mul"]);
        let x = Tensor::new(vec![1, 4], vec![1.0, 2.0, -1.0, 0.5]);
        let y0 = execute_simple(&g0, &x).unwrap();
        let y1 = execute_simple(&g1, &x).unwrap();
        for (a, b) in y0.as_f32().unwrap().iter().zip(y1.as_f32().unwrap()) {
            assert!((a - b).abs() < 1e-5);
        }
    }

    #[test]
    fn chained_scales_merge() {
        let mut b = GraphBuilder::new("m");
        b.input("x", vec![1, 2]);
        b.scalar("s1", 2.0);
        b.scalar("s2", 3.0);
        b.node("Mul", &["x", "s1"], &["a"], &[]);
        b.node("Mul", &["a", "s2"], &["y"], &[]);
        b.output("y", vec![1, 2]);
        let mut g = b.finish().unwrap();
        assert!(propagate_dequant(&mut g).unwrap());
        assert_eq!(g.nodes.len(), 1);
        let x = Tensor::new(vec![1, 2], vec![1.0, -2.0]);
        assert_eq!(execute_simple(&g, &x).unwrap().as_f32().unwrap(), &[6.0, -12.0]);
    }

    #[test]
    fn scale_stops_at_nonlinearity() {
        let mut b = GraphBuilder::new("nl");
        b.input("x", vec![1, 2]);
        b.scalar("s", 2.0);
        b.node("Mul", &["x", "s"], &["a"], &[]);
        b.node("Sigmoid", &["a"], &["y"], &[]);
        b.output("y", vec![1, 2]);
        let mut g = b.finish().unwrap();
        assert!(!propagate_dequant(&mut g).unwrap());
        assert_eq!(g.nodes.len(), 2);
    }

    #[test]
    fn two_layer_stack_scales_end_up_last() {
        // W-quantized 2-layer MLP with ReLU between: scales propagate to
        // just after each matmul but not across the relu
        let mut b = GraphBuilder::new("two");
        b.input("x", vec![1, 4]);
        b.initializer("w1", Tensor::new(vec![4, 4], (0..16).map(|v| (v as f32 - 8.0) * 0.1).collect()));
        b.quant("w1", "w1q", 0.125, 0.0, 4.0, true, false, "ROUND");
        b.node("MatMul", &["x", "w1q"], &["h"], &[]);
        b.node("Relu", &["h"], &["hr"], &[]);
        b.initializer("w2", Tensor::new(vec![4, 2], (0..8).map(|v| (v as f32 - 4.0) * 0.2).collect()));
        b.quant("w2", "w2q", 0.125, 0.0, 4.0, true, false, "ROUND");
        b.node("MatMul", &["hr", "w2q"], &["y"], &[]);
        b.output("y", vec![1, 2]);
        let g0 = b.finish().unwrap();
        let mut g1 = g0.clone();
        hls4ml_ingest(&mut g1).unwrap();
        let order: Vec<&str> = g1.nodes.iter().map(|n| n.op_type.as_str()).collect();
        assert_eq!(order, vec!["MatMul", "Mul", "Relu", "MatMul", "Mul"]);
        let x = Tensor::new(vec![1, 4], vec![0.5, -1.0, 2.0, 1.0]);
        let y0 = execute_simple(&g0, &x).unwrap();
        let y1 = execute_simple(&g1, &x).unwrap();
        for (a, b) in y0.as_f32().unwrap().iter().zip(y1.as_f32().unwrap()) {
            assert!((a - b).abs() < 1e-5);
        }
    }
}

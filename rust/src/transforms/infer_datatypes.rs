//! Per-tensor arbitrary-precision datatype inference.
//!
//! Walks the graph in topological order propagating integer value ranges
//! — including accumulator growth through `MatMul`/`Conv` — and annotates
//! every tensor with the smallest covering [`DataType`]. This implements
//! the paper's §V observation that fine-grained magnitude bounds let one
//! "assess whether the operation might overflow given a certain number of
//! output accumulation bits".

use super::quant_params_static;
use crate::datatypes::DataType;
use crate::ir::ModelGraph;
use crate::tensor::Tensor;
use anyhow::Result;

/// Closed value interval tracked per tensor.
#[derive(Debug, Clone, Copy)]
struct Range {
    lo: f64,
    hi: f64,
    /// all values on the integer grid?
    integral: bool,
}

impl Range {
    fn dt(&self) -> DataType {
        if self.integral {
            DataType::smallest_covering(self.lo, self.hi)
        } else {
            DataType::Float32
        }
    }
}

fn range_of_tensor(t: &Tensor) -> Range {
    let vals = t.to_f64_vec();
    let lo = vals.iter().copied().fold(f64::INFINITY, f64::min);
    let hi = vals.iter().copied().fold(f64::NEG_INFINITY, f64::max);
    let integral = vals.iter().all(|v| v.fract() == 0.0);
    Range { lo: lo.min(hi), hi: hi.max(lo), integral }
}

fn range_of_dt(dt: DataType) -> Option<Range> {
    match dt {
        DataType::Float32 => None,
        d => Some(Range { lo: d.min(), hi: d.max(), integral: d.is_integer() }),
    }
}

/// Infer and annotate datatypes for all tensors. Returns true if any
/// annotation changed. Run after shapes are known.
pub fn infer_datatypes(graph: &mut ModelGraph) -> Result<bool> {
    graph.sort_topologically()?;
    let mut ranges: std::collections::BTreeMap<String, Range> = Default::default();
    // seeds: initializers (from values, refined by explicit annotations)
    for (name, t) in &graph.initializers {
        let r = match range_of_dt(graph.tensor_datatype(name)) {
            Some(r) => r,
            None => range_of_tensor(t),
        };
        ranges.insert(name.clone(), r);
    }
    for vi in &graph.inputs {
        if let Some(r) = range_of_dt(vi.dtype) {
            ranges.insert(vi.name.clone(), r);
        }
    }

    let nodes = graph.nodes.clone();
    for node in &nodes {
        let get = |i: usize| -> Option<Range> { node.inputs.get(i).and_then(|n| ranges.get(n)).copied() };
        let out_range: Option<Range> = match node.op_type.as_str() {
            "Quant" => {
                // static params: exact output grid
                quant_params_static(graph, node).ok().map(|p| {
                    let (qlo, qhi) = crate::ops::quant::quant_bounds(p.signed, p.narrow, p.bit_width);
                    let s = f64::from(p.scale);
                    let z = f64::from(p.zero_point);
                    Range {
                        lo: (qlo - z) * s,
                        hi: (qhi - z) * s,
                        integral: s == 1.0 && z.fract() == 0.0,
                    }
                })
            }
            "BipolarQuant" => {
                let s = graph.initializer(&node.inputs[1]).and_then(|t| t.scalar_value().ok());
                s.map(|s| Range { lo: -f64::from(s), hi: f64::from(s), integral: s == 1.0 })
            }
            "MultiThreshold" => {
                let t = graph.initializer(&node.inputs[1]);
                t.map(|t| {
                    let steps = t.shape()[1] as f64;
                    let os = f64::from(node.attr_float_or("out_scale", 1.0));
                    let ob = f64::from(node.attr_float_or("out_bias", 0.0));
                    let (a, b) = (ob, os * steps + ob);
                    Range {
                        lo: a.min(b),
                        hi: a.max(b),
                        integral: os.fract() == 0.0 && ob.fract() == 0.0,
                    }
                })
            }
            "Relu" => get(0).map(|r| Range { lo: r.lo.max(0.0), hi: r.hi.max(0.0), integral: r.integral }),
            "MaxPool" | "Reshape" | "Transpose" | "Flatten" | "Identity" | "Squeeze" | "Unsqueeze"
            | "Pad" | "Gather" => get(0),
            "Concat" => {
                let mut acc: Option<Range> = None;
                for i in 0..node.inputs.len() {
                    match (acc, get(i)) {
                        (None, r) => acc = r,
                        (Some(a), Some(b)) => {
                            acc = Some(Range {
                                lo: a.lo.min(b.lo),
                                hi: a.hi.max(b.hi),
                                integral: a.integral && b.integral,
                            })
                        }
                        (Some(_), None) => acc = None,
                    }
                    if acc.is_none() {
                        break;
                    }
                }
                acc
            }
            "Add" | "Sub" => match (get(0), get(1)) {
                (Some(a), Some(b)) => {
                    let (blo, bhi) = if node.op_type == "Sub" { (-b.hi, -b.lo) } else { (b.lo, b.hi) };
                    Some(Range { lo: a.lo + blo, hi: a.hi + bhi, integral: a.integral && b.integral })
                }
                _ => None,
            },
            "Mul" => match (get(0), get(1)) {
                (Some(a), Some(b)) => {
                    let cands = [a.lo * b.lo, a.lo * b.hi, a.hi * b.lo, a.hi * b.hi];
                    Some(Range {
                        lo: cands.iter().copied().fold(f64::INFINITY, f64::min),
                        hi: cands.iter().copied().fold(f64::NEG_INFINITY, f64::max),
                        integral: a.integral && b.integral,
                    })
                }
                _ => None,
            },
            "MatMul" | "Conv" | "MatMulInteger" | "ConvInteger" => {
                // accumulator growth: k products summed
                match (get(0), get(1)) {
                    (Some(a), Some(b)) => {
                        let k = dot_length(graph, node);
                        k.map(|k| {
                            let cands = [a.lo * b.lo, a.lo * b.hi, a.hi * b.lo, a.hi * b.hi];
                            let plo = cands.iter().copied().fold(f64::INFINITY, f64::min);
                            let phi = cands.iter().copied().fold(f64::NEG_INFINITY, f64::max);
                            Range {
                                lo: plo * k as f64,
                                hi: phi * k as f64,
                                integral: a.integral && b.integral,
                            }
                        })
                    }
                    _ => None,
                }
            }
            _ => None,
        };
        if let Some(r) = out_range {
            for o in &node.outputs {
                ranges.insert(o.clone(), r);
            }
        }
    }

    let mut changed = false;
    for (name, r) in &ranges {
        if graph.is_input(name) || graph.initializers.contains_key(name) {
            continue;
        }
        let dt = r.dt();
        if graph.tensor_datatype(name) != dt {
            graph.set_tensor_datatype(name, dt);
            changed = true;
        }
    }
    Ok(changed)
}

/// Reduction length of a MatMul/Conv: inner dim (times kernel area and
/// divided by groups for Conv).
fn dot_length(graph: &ModelGraph, node: &crate::ir::Node) -> Option<usize> {
    let w_shape = graph.tensor_shape(&node.inputs[1])?;
    match node.op_type.as_str() {
        "MatMul" | "MatMulInteger" => Some(w_shape[0]),
        _ => {
            // Conv weights [M, C/g, kh, kw]
            if w_shape.len() == 4 {
                Some(w_shape[1] * w_shape[2] * w_shape[3])
            } else {
                None
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ir::GraphBuilder;
    use crate::transforms::cleanup;

    #[test]
    fn quant_output_annotated() {
        let mut b = GraphBuilder::new("q");
        b.input("x", vec![1, 4]);
        b.quant("x", "y", 1.0, 0.0, 4.0, true, false, "ROUND");
        b.output("y", vec![1, 4]);
        let mut g = b.finish().unwrap();
        infer_datatypes(&mut g).unwrap();
        assert_eq!(g.tensor_datatype("y"), DataType::Int(4));
    }

    #[test]
    fn accumulator_width_through_matmul() {
        // int4 activations x int4 weights over k=64: |acc| <= 64*8*8 = 4096
        let mut b = GraphBuilder::new("acc");
        b.input("x", vec![1, 64]);
        b.quant("x", "xq", 1.0, 0.0, 4.0, true, false, "ROUND");
        b.initializer("w", Tensor::full(vec![64, 8], 3.0));
        b.node("MatMul", &["xq", "w"], &["y"], &[]);
        b.output("y", vec![1, 8]);
        let mut g = b.finish().unwrap();
        cleanup(&mut g).unwrap();
        infer_datatypes(&mut g).unwrap();
        // w in [3,3] integral; xq in [-8,7] -> acc in [-1536, 1344] -> INT12
        assert_eq!(g.tensor_datatype("y"), DataType::Int(12));
    }

    #[test]
    fn relu_makes_unsigned() {
        let mut b = GraphBuilder::new("r");
        b.input("x", vec![1, 4]);
        b.quant("x", "xq", 1.0, 0.0, 8.0, true, false, "ROUND");
        b.node("Relu", &["xq"], &["y"], &[]);
        b.output("y", vec![1, 4]);
        let mut g = b.finish().unwrap();
        infer_datatypes(&mut g).unwrap();
        assert_eq!(g.tensor_datatype("y"), DataType::Uint(7));
    }

    #[test]
    fn scaled_quant_not_integral() {
        let mut b = GraphBuilder::new("s");
        b.input("x", vec![1, 4]);
        b.quant("x", "y", 0.5, 0.0, 4.0, true, false, "ROUND");
        b.output("y", vec![1, 4]);
        let mut g = b.finish().unwrap();
        infer_datatypes(&mut g).unwrap();
        assert_eq!(g.tensor_datatype("y"), DataType::Float32);
    }

    #[test]
    fn multithreshold_range() {
        let mut b = GraphBuilder::new("mt");
        b.input("x", vec![1, 2]);
        b.initializer("t", Tensor::new(vec![1, 3], vec![0.5, 1.5, 2.5]));
        b.node_in_domain(crate::ir::DOMAIN_FINN, "MultiThreshold", &["x", "t"], &["y"], &[]);
        b.output("y", vec![1, 2]);
        let mut g = b.finish().unwrap();
        infer_datatypes(&mut g).unwrap();
        assert_eq!(g.tensor_datatype("y"), DataType::Uint(2));
    }

    #[test]
    fn bipolar_weights_detected_from_values() {
        let mut b = GraphBuilder::new("bw");
        b.input("x", vec![1, 2]);
        b.initializer("w", Tensor::new(vec![2, 2], vec![1.0, -1.0, -1.0, 1.0]));
        b.node("MatMul", &["x", "w"], &["y"], &[]);
        b.output("y", vec![1, 2]);
        let mut g = b.finish().unwrap();
        b"";
        infer_datatypes(&mut g).unwrap();
        // x unknown float -> y stays float; but w's range seeds exist
        assert_eq!(g.tensor_datatype("y"), DataType::Float32);
    }
}

//! Per-tensor arbitrary-precision datatype inference.
//!
//! Walks the graph in topological order propagating integer value ranges
//! — including accumulator growth through `MatMul`/`Conv` — and annotates
//! every tensor with the smallest covering [`DataType`]. This implements
//! the paper's §V observation that fine-grained magnitude bounds let one
//! "assess whether the operation might overflow given a certain number of
//! output accumulation bits".
//!
//! The range-propagation engine is exported as [`infer_ranges`] /
//! [`ValueRange`] so other layers can consume the same proofs:
//! [`crate::streamline`] drives its integer-domain lowering with them, and
//! the plan compiler ([`crate::plan`]) uses them to decide when a
//! `Conv`/`Gemm`/`MatMul` may run on the quantized `i8`/`i32` kernel tier.
//! `integral == true` is a *literal* claim — every value the tensor can
//! hold is an integer (step-1 grid) — which is exactly the property the
//! integer kernels need.

use super::quant_params_static;
use crate::datatypes::DataType;
use crate::ir::ModelGraph;
use crate::ops::quant::{round_half_even, RoundingMode};
use crate::tensor::Tensor;
use anyhow::Result;
use std::collections::BTreeMap;

/// Closed value interval tracked per tensor.
///
/// `integral` means every representable value is a literal integer; the
/// interval bounds are then exact integer bounds. Non-integral ranges
/// still carry magnitude information (used for overflow analysis) but
/// never qualify a tensor for the integer kernel tier.
#[derive(Debug, Clone, Copy)]
pub struct ValueRange {
    pub lo: f64,
    pub hi: f64,
    /// all values on the step-1 integer grid?
    pub integral: bool,
}

impl ValueRange {
    fn dt(&self) -> DataType {
        if self.integral {
            DataType::smallest_covering(self.lo, self.hi)
        } else {
            DataType::Float32
        }
    }
}

fn range_of_tensor(t: &Tensor) -> ValueRange {
    let vals = t.to_f64_vec();
    let lo = vals.iter().copied().fold(f64::INFINITY, f64::min);
    let hi = vals.iter().copied().fold(f64::NEG_INFINITY, f64::max);
    let integral = vals.iter().all(|v| v.fract() == 0.0);
    ValueRange { lo: lo.min(hi), hi: hi.max(lo), integral }
}

fn range_of_dt(dt: DataType) -> Option<ValueRange> {
    match dt {
        DataType::Float32 => None,
        // SCALEDINT<n> is integer *levels* times an unknown float scale:
        // neither the value bounds nor literal integrality can be claimed
        DataType::ScaledInt(_) => None,
        d => Some(ValueRange { lo: d.min(), hi: d.max(), integral: d.is_integer() }),
    }
}

/// Propagate value ranges through the graph (no mutation). Seeds come
/// from initializer values (refined by explicit datatype annotations) and
/// annotated input datatypes; node rules cover the quantization dialect,
/// shape-preserving ops, elementwise arithmetic, and `MatMul`/`Conv`
/// accumulator growth. Tensors without a derivable range are absent from
/// the returned map (i.e. unconstrained float).
pub fn infer_ranges(graph: &ModelGraph) -> Result<BTreeMap<String, ValueRange>> {
    let order = graph.topo_order()?;
    let mut ranges: BTreeMap<String, ValueRange> = Default::default();
    // seeds: initializers (from values, refined by explicit annotations)
    for (name, t) in &graph.initializers {
        let r = match range_of_dt(graph.tensor_datatype(name)) {
            Some(r) => r,
            None => range_of_tensor(t),
        };
        ranges.insert(name.clone(), r);
    }
    for vi in &graph.inputs {
        if let Some(r) = range_of_dt(vi.dtype) {
            ranges.insert(vi.name.clone(), r);
        }
    }

    for &ni in &order {
        let node = &graph.nodes[ni];
        let get =
            |i: usize| -> Option<ValueRange> { node.inputs.get(i).and_then(|n| ranges.get(n)).copied() };
        let out_range: Option<ValueRange> = match node.op_type.as_str() {
            "Quant" => {
                // static params: exact output grid
                quant_params_static(graph, node).ok().map(|p| {
                    let (qlo, qhi) = crate::ops::quant::quant_bounds(p.signed, p.narrow, p.bit_width);
                    let s = f64::from(p.scale);
                    let z = f64::from(p.zero_point);
                    ValueRange {
                        lo: (qlo - z) * s,
                        hi: (qhi - z) * s,
                        integral: s == 1.0 && z.fract() == 0.0,
                    }
                })
            }
            "BipolarQuant" => {
                let s = graph.initializer(&node.inputs[1]).and_then(|t| t.scalar_value().ok());
                s.map(|s| ValueRange { lo: -f64::from(s), hi: f64::from(s), integral: s == 1.0 })
            }
            "MultiThreshold" => {
                let t = graph.initializer(&node.inputs[1]);
                t.map(|t| {
                    let steps = t.shape()[1] as f64;
                    let os = f64::from(node.attr_float_or("out_scale", 1.0));
                    let ob = f64::from(node.attr_float_or("out_bias", 0.0));
                    let (a, b) = (ob, os * steps + ob);
                    ValueRange {
                        lo: a.min(b),
                        hi: a.max(b),
                        integral: os.fract() == 0.0 && ob.fract() == 0.0,
                    }
                })
            }
            "Trunc" => trunc_range(graph, node, get(0)),
            "Relu" => get(0).map(|r| ValueRange {
                lo: r.lo.max(0.0),
                hi: r.hi.max(0.0),
                integral: r.integral,
            }),
            "MaxPool" | "Reshape" | "Transpose" | "Flatten" | "Identity" | "Squeeze" | "Unsqueeze"
            | "Pad" | "Gather" => get(0),
            "Concat" => {
                let mut acc: Option<ValueRange> = None;
                for i in 0..node.inputs.len() {
                    match (acc, get(i)) {
                        (None, r) => acc = r,
                        (Some(a), Some(b)) => {
                            acc = Some(ValueRange {
                                lo: a.lo.min(b.lo),
                                hi: a.hi.max(b.hi),
                                integral: a.integral && b.integral,
                            })
                        }
                        (Some(_), None) => acc = None,
                    }
                    if acc.is_none() {
                        break;
                    }
                }
                acc
            }
            "Add" | "Sub" => match (get(0), get(1)) {
                (Some(a), Some(b)) => {
                    let (blo, bhi) = if node.op_type == "Sub" { (-b.hi, -b.lo) } else { (b.lo, b.hi) };
                    Some(ValueRange {
                        lo: a.lo + blo,
                        hi: a.hi + bhi,
                        integral: a.integral && b.integral,
                    })
                }
                _ => None,
            },
            "Mul" => match (get(0), get(1)) {
                (Some(a), Some(b)) => {
                    let cands = [a.lo * b.lo, a.lo * b.hi, a.hi * b.lo, a.hi * b.hi];
                    Some(ValueRange {
                        lo: cands.iter().copied().fold(f64::INFINITY, f64::min),
                        hi: cands.iter().copied().fold(f64::NEG_INFINITY, f64::max),
                        integral: a.integral && b.integral,
                    })
                }
                _ => None,
            },
            "MatMul" | "Conv" | "MatMulInteger" | "ConvInteger" => {
                // accumulator growth: k products summed
                match (get(0), get(1)) {
                    (Some(a), Some(b)) => {
                        let k = dot_length(graph, node);
                        k.map(|k| {
                            let cands = [a.lo * b.lo, a.lo * b.hi, a.hi * b.lo, a.hi * b.hi];
                            let plo = cands.iter().copied().fold(f64::INFINITY, f64::min);
                            let phi = cands.iter().copied().fold(f64::NEG_INFINITY, f64::max);
                            ValueRange {
                                lo: plo.min(0.0) * k as f64,
                                hi: phi.max(0.0) * k as f64,
                                integral: a.integral && b.integral,
                            }
                        })
                    }
                    _ => None,
                }
            }
            "Gemm" => gemm_range(graph, node, get(0), get(1), get(2)),
            _ => None,
        };
        if let Some(r) = out_range {
            for o in &node.outputs {
                ranges.insert(o.clone(), r);
            }
        }
    }
    Ok(ranges)
}

/// Range rule for `Trunc` with static scalar parameters: recover the
/// integer bounds under the declared input quantization, apply the
/// monotone right-shift, and re-apply scale/zero-point. 1-bit outputs are
/// legal (the executor accepts them since PR 3).
fn trunc_range(
    graph: &ModelGraph,
    node: &crate::ir::Node,
    input: Option<ValueRange>,
) -> Option<ValueRange> {
    let input = input?;
    let scalar = |i: usize| -> Option<f64> {
        let t = graph.initializer(node.inputs.get(i)?)?;
        if t.numel() != 1 {
            return None;
        }
        t.scalar_value().ok().map(f64::from)
    };
    let s = scalar(1)?;
    let z = scalar(2)?;
    let ibw = scalar(3)?;
    let obw = scalar(4)?;
    if s <= 0.0 || ibw < obw || obw < 1.0 {
        return None; // the op itself rejects these; no range claim
    }
    let mode = RoundingMode::from_str(&node.attr_str_or("rounding_mode", "FLOOR")).ok()?;
    let shift = 2f64.powf(ibw - obw);
    // monotone per element: bounds map to bounds
    let q_of = |v: f64| -> f64 { mode.apply(round_half_even(v / s + z) / shift) };
    let lo = (q_of(input.lo) - z) * s;
    let hi = (q_of(input.hi) - z) * s;
    Some(ValueRange {
        lo: lo.min(hi),
        hi: hi.max(lo),
        integral: s == 1.0 && z.fract() == 0.0,
    })
}

/// Range rule for `Gemm`: `alpha * (A @ B) + beta * C` — the MatMul-style
/// accumulator bound scaled by `alpha`, plus the (broadcast) `beta * C`
/// interval when a C input is present. The reduction length comes from
/// B's shape honoring `transB`. Integral only when the accumulator and
/// scaled bias both stay on the step-1 grid.
fn gemm_range(
    graph: &ModelGraph,
    node: &crate::ir::Node,
    a: Option<ValueRange>,
    b: Option<ValueRange>,
    c: Option<ValueRange>,
) -> Option<ValueRange> {
    let (a, b) = (a?, b?);
    let w_shape = graph.tensor_shape(&node.inputs[1])?;
    if w_shape.len() != 2 {
        return None;
    }
    let trans_b = node.attr_int_or("transB", 0) != 0;
    let k = if trans_b { w_shape[1] } else { w_shape[0] };
    let alpha = f64::from(node.attr_float_or("alpha", 1.0));
    let cands = [a.lo * b.lo, a.lo * b.hi, a.hi * b.lo, a.hi * b.hi];
    let plo = cands.iter().copied().fold(f64::INFINITY, f64::min);
    let phi = cands.iter().copied().fold(f64::NEG_INFINITY, f64::max);
    let (acc_lo, acc_hi) = (plo.min(0.0) * k as f64, phi.max(0.0) * k as f64);
    let (mut lo, mut hi) = (
        (alpha * acc_lo).min(alpha * acc_hi),
        (alpha * acc_lo).max(alpha * acc_hi),
    );
    let mut integral = a.integral && b.integral && alpha.fract() == 0.0;
    let has_c = node.inputs.get(2).map(String::as_str).is_some_and(|s| !s.is_empty());
    if has_c {
        let c = c?; // C present but unconstrained: no claim at all
        let beta = f64::from(node.attr_float_or("beta", 1.0));
        let (blo, bhi) = ((beta * c.lo).min(beta * c.hi), (beta * c.lo).max(beta * c.hi));
        lo += blo;
        hi += bhi;
        integral = integral && c.integral && beta.fract() == 0.0;
    }
    Some(ValueRange { lo, hi, integral })
}

/// Infer and annotate datatypes for all tensors. Returns true if any
/// annotation changed. Run after shapes are known.
///
/// Tensors on a literal integer grid get the smallest covering
/// `INT`/`UINT`; a `Quant` output whose grid carries a non-unit scale (or
/// fractional zero point) is annotated `SCALEDINT<n>` — integer levels of
/// unknown float scale — instead of falling all the way back to FLOAT32.
pub fn infer_datatypes(graph: &mut ModelGraph) -> Result<bool> {
    graph.sort_topologically()?;
    let ranges = infer_ranges(graph)?;

    // SCALEDINT refinement: Quant outputs that are integer *levels* times
    // a non-unit scale (the range pass reports these as non-integral).
    let mut scaled: BTreeMap<String, DataType> = BTreeMap::new();
    for node in &graph.nodes {
        if node.op_type != "Quant" {
            continue;
        }
        if let Ok(p) = quant_params_static(graph, node) {
            let unit = f64::from(p.scale) == 1.0 && f64::from(p.zero_point).fract() == 0.0;
            if !unit {
                let bits = (p.bit_width.ceil().max(1.0) as u8).min(64);
                for o in &node.outputs {
                    scaled.insert(o.clone(), DataType::ScaledInt(bits));
                }
            }
        }
    }

    let mut changed = false;
    for (name, r) in &ranges {
        if graph.is_input(name) || graph.initializers.contains_key(name) {
            continue;
        }
        let dt = match scaled.get(name) {
            Some(&d) if !r.integral => d,
            _ => r.dt(),
        };
        if graph.tensor_datatype(name) != dt {
            graph.set_tensor_datatype(name, dt);
            changed = true;
        }
    }
    Ok(changed)
}

/// Reduction length of a MatMul/Conv: inner dim (times kernel area and
/// divided by groups for Conv).
fn dot_length(graph: &ModelGraph, node: &crate::ir::Node) -> Option<usize> {
    let w_shape = graph.tensor_shape(&node.inputs[1])?;
    match node.op_type.as_str() {
        "MatMul" | "MatMulInteger" => Some(w_shape[0]),
        _ => {
            // Conv weights [M, C/g, kh, kw]
            if w_shape.len() == 4 {
                Some(w_shape[1] * w_shape[2] * w_shape[3])
            } else {
                None
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ir::GraphBuilder;
    use crate::transforms::cleanup;

    #[test]
    fn quant_output_annotated() {
        let mut b = GraphBuilder::new("q");
        b.input("x", vec![1, 4]);
        b.quant("x", "y", 1.0, 0.0, 4.0, true, false, "ROUND");
        b.output("y", vec![1, 4]);
        let mut g = b.finish().unwrap();
        infer_datatypes(&mut g).unwrap();
        assert_eq!(g.tensor_datatype("y"), DataType::Int(4));
    }

    #[test]
    fn accumulator_width_through_matmul() {
        // int4 activations x int4 weights over k=64: |acc| <= 64*8*8 = 4096
        let mut b = GraphBuilder::new("acc");
        b.input("x", vec![1, 64]);
        b.quant("x", "xq", 1.0, 0.0, 4.0, true, false, "ROUND");
        b.initializer("w", Tensor::full(vec![64, 8], 3.0));
        b.node("MatMul", &["xq", "w"], &["y"], &[]);
        b.output("y", vec![1, 8]);
        let mut g = b.finish().unwrap();
        cleanup(&mut g).unwrap();
        infer_datatypes(&mut g).unwrap();
        // w in [3,3] integral; xq in [-8,7] -> acc in [-1536, 1344] -> INT12
        assert_eq!(g.tensor_datatype("y"), DataType::Int(12));
    }

    #[test]
    fn relu_makes_unsigned() {
        let mut b = GraphBuilder::new("r");
        b.input("x", vec![1, 4]);
        b.quant("x", "xq", 1.0, 0.0, 8.0, true, false, "ROUND");
        b.node("Relu", &["xq"], &["y"], &[]);
        b.output("y", vec![1, 4]);
        let mut g = b.finish().unwrap();
        infer_datatypes(&mut g).unwrap();
        assert_eq!(g.tensor_datatype("y"), DataType::Uint(7));
    }

    #[test]
    fn scaled_quant_gets_scaledint() {
        // non-unit scale: integer levels of unknown float scale, not FLOAT32
        let mut b = GraphBuilder::new("s");
        b.input("x", vec![1, 4]);
        b.quant("x", "y", 0.5, 0.0, 4.0, true, false, "ROUND");
        b.output("y", vec![1, 4]);
        let mut g = b.finish().unwrap();
        infer_datatypes(&mut g).unwrap();
        assert_eq!(g.tensor_datatype("y"), DataType::ScaledInt(4));
        // the range itself stays non-integral: SCALEDINT never qualifies a
        // tensor for the literal-integer kernel tier
        let ranges = infer_ranges(&g).unwrap();
        assert!(!ranges["y"].integral);
    }

    #[test]
    fn multithreshold_range() {
        let mut b = GraphBuilder::new("mt");
        b.input("x", vec![1, 2]);
        b.initializer("t", Tensor::new(vec![1, 3], vec![0.5, 1.5, 2.5]));
        b.node_in_domain(crate::ir::DOMAIN_FINN, "MultiThreshold", &["x", "t"], &["y"], &[]);
        b.output("y", vec![1, 2]);
        let mut g = b.finish().unwrap();
        infer_datatypes(&mut g).unwrap();
        assert_eq!(g.tensor_datatype("y"), DataType::Uint(2));
    }

    #[test]
    fn bipolar_weights_detected_from_values() {
        let mut b = GraphBuilder::new("bw");
        b.input("x", vec![1, 2]);
        b.initializer("w", Tensor::new(vec![2, 2], vec![1.0, -1.0, -1.0, 1.0]));
        b.node("MatMul", &["x", "w"], &["y"], &[]);
        b.output("y", vec![1, 2]);
        let mut g = b.finish().unwrap();
        infer_datatypes(&mut g).unwrap();
        // x unknown float -> y stays float; but w's range seeds exist
        assert_eq!(g.tensor_datatype("y"), DataType::Float32);
    }

    #[test]
    fn bipolar_activation_times_bipolar_weights_accumulates() {
        // BIPOLAR x BIPOLAR over k=64: acc in [-64, 64] -> INT8; the
        // bipolar grid (s = 1) is a literal integer grid, so the
        // accumulator proof goes through.
        let mut b = GraphBuilder::new("bip");
        b.input("x", vec![1, 64]);
        b.bipolar_quant("x", "xq", 1.0);
        b.initializer("w", Tensor::new(vec![64, 4], vec![1.0; 256]));
        b.node("MatMul", &["xq", "w"], &["y"], &[]);
        b.output("y", vec![1, 4]);
        let mut g = b.finish().unwrap();
        cleanup(&mut g).unwrap();
        infer_datatypes(&mut g).unwrap();
        assert_eq!(g.tensor_datatype("y"), DataType::Int(8));
        let ranges = infer_ranges(&g).unwrap();
        assert!(ranges["xq"].integral);
        assert_eq!((ranges["y"].lo, ranges["y"].hi), (-64.0, 64.0));
        // scaled bipolar (s != 1) is NOT a literal integer grid
        let mut b2 = GraphBuilder::new("bip2");
        b2.input("x", vec![1, 4]);
        b2.bipolar_quant("x", "y", 0.5);
        b2.output("y", vec![1, 4]);
        let mut g2 = b2.finish().unwrap();
        infer_datatypes(&mut g2).unwrap();
        assert_eq!(g2.tensor_datatype("y"), DataType::Float32);
    }

    #[test]
    fn ternary_weights_through_matmul() {
        // TERNARY annotation on the weights: [-1, 1] integral; uint8
        // activations over k=16 -> acc in [-4080, 4080] -> INT13
        let mut b = GraphBuilder::new("tern");
        b.input("x", vec![1, 16]);
        b.quant("x", "xq", 1.0, 0.0, 8.0, false, false, "ROUND");
        b.initializer("w", Tensor::new(vec![16, 2], vec![1.0; 32]));
        b.node("MatMul", &["xq", "w"], &["y"], &[]);
        b.output("y", vec![1, 2]);
        let mut g = b.finish().unwrap();
        cleanup(&mut g).unwrap();
        g.set_tensor_datatype("w", DataType::Ternary);
        infer_datatypes(&mut g).unwrap();
        assert_eq!(g.tensor_datatype("y"), DataType::Int(13));
    }

    #[test]
    fn gemm_accumulator_range_with_bias() {
        // int4 activations x [3,3]-integral weights (transB, k=16) plus an
        // integral beta*C: acc in [-8*3*16, 7*3*16] + 2*[-5, 5]
        let mut b = GraphBuilder::new("gemmacc");
        b.input("x", vec![1, 16]);
        b.quant("x", "xq", 1.0, 0.0, 4.0, true, false, "ROUND");
        b.initializer("w", Tensor::full(vec![8, 16], 3.0)); // transB: [n, k]
        b.initializer("c", Tensor::new(vec![1, 8], vec![5.0, -5.0, 0.0, 1.0, 2.0, 3.0, 4.0, -1.0]));
        b.node(
            "Gemm",
            &["xq", "w", "c"],
            &["y"],
            &[
                ("transB", crate::ir::AttrValue::Int(1)),
                ("beta", crate::ir::AttrValue::Float(2.0)),
            ],
        );
        b.output("y", vec![1, 8]);
        let mut g = b.finish().unwrap();
        cleanup(&mut g).unwrap();
        let ranges = infer_ranges(&g).unwrap();
        let r = ranges["y"];
        assert!(r.integral, "integral accumulator + integral bias");
        assert_eq!((r.lo, r.hi), (-8.0 * 3.0 * 16.0 - 10.0, 7.0 * 3.0 * 16.0 + 10.0));
        // fractional beta drops the integral claim but keeps the bound
        let mut g2 = g.clone();
        for n in g2.nodes.iter_mut() {
            if n.op_type == "Gemm" {
                n.attrs.insert("beta".to_string(), crate::ir::AttrValue::Float(0.5));
            }
        }
        let r2 = infer_ranges(&g2).unwrap()["y"];
        assert!(!r2.integral);
    }

    #[test]
    fn trunc_one_bit_output_range() {
        // uint2 input truncated 2 -> 1 bit: q/2 floored lands in {0, 1}
        let mut b = GraphBuilder::new("tr");
        b.input("x", vec![1, 4]);
        b.quant("x", "xq", 1.0, 0.0, 2.0, false, false, "ROUND");
        b.scalar("s", 1.0);
        b.scalar("z", 0.0);
        b.scalar("ib", 2.0);
        b.scalar("ob", 1.0);
        b.node_in_domain(
            crate::ir::DOMAIN_QONNX,
            "Trunc",
            &["xq", "s", "z", "ib", "ob"],
            &["y"],
            &[],
        );
        b.output("y", vec![1, 4]);
        let mut g = b.finish().unwrap();
        infer_datatypes(&mut g).unwrap();
        assert_eq!(g.tensor_datatype("y"), DataType::Uint(1));
        let ranges = infer_ranges(&g).unwrap();
        assert_eq!((ranges["y"].lo, ranges["y"].hi), (0.0, 1.0));
        assert!(ranges["y"].integral);
    }

    #[test]
    fn trunc_scaled_range_not_integral() {
        // scale 0.5 input: the truncated grid keeps the scale, so the
        // range is known but not a literal integer grid
        let mut b = GraphBuilder::new("trs");
        b.input("x", vec![1, 2]);
        b.quant("x", "xq", 0.5, 0.0, 8.0, false, false, "ROUND");
        b.scalar("s", 0.5);
        b.scalar("z", 0.0);
        b.scalar("ib", 8.0);
        b.scalar("ob", 4.0);
        b.node_in_domain(
            crate::ir::DOMAIN_QONNX,
            "Trunc",
            &["xq", "s", "z", "ib", "ob"],
            &["y"],
            &[],
        );
        b.output("y", vec![1, 2]);
        let mut g = b.finish().unwrap();
        infer_datatypes(&mut g).unwrap();
        assert_eq!(g.tensor_datatype("y"), DataType::Float32);
        let ranges = infer_ranges(&g).unwrap();
        assert!(!ranges["y"].integral);
        // uint8 levels [0, 255] scaled by 0.5, shifted 4 bits: q in
        // [0, 15], value = 0.5 * q in [0, 7.5]
        assert_eq!((ranges["y"].lo, ranges["y"].hi), (0.0, 7.5));
    }
}

//! Execution-based shape inference.
//!
//! Rather than maintaining a second per-op shape function that can drift
//! from the executor, we infer shapes by executing the graph on zero-filled
//! inputs and recording every intermediate's shape — exact by construction,
//! which is what a *verification-oriented* toolkit wants (the paper's own
//! execution engine makes the same trade).

use crate::exec::{execute_with, ExecOptions};
use crate::ir::ModelGraph;
use crate::tensor::Tensor;
use anyhow::{Context, Result};
use std::collections::BTreeMap;

/// Annotate every intermediate and output tensor with its static shape.
/// Requires all graph inputs to have declared shapes.
pub fn infer_shapes(graph: &mut ModelGraph) -> Result<bool> {
    let mut inputs = BTreeMap::new();
    for vi in &graph.inputs {
        if graph.initializers.contains_key(&vi.name) {
            continue;
        }
        let shape = vi
            .shape
            .clone()
            .with_context(|| format!("input '{}' has no declared shape", vi.name))?;
        inputs.insert(vi.name.clone(), Tensor::zeros(shape));
    }
    let opts = ExecOptions { keep_intermediates: true, ..Default::default() };
    let result = execute_with(graph, &inputs, &opts).context("shape inference execution")?;
    let mut changed = false;
    for (name, t) in &result.intermediates {
        if graph.is_input(name) || graph.initializers.contains_key(name) {
            continue;
        }
        let shape = t.shape().to_vec();
        if graph.tensor_shape(name).as_deref() != Some(&shape[..]) {
            graph.set_tensor_shape(name, shape);
            changed = true;
        }
    }
    Ok(changed)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ir::GraphBuilder;

    #[test]
    fn infers_conv_chain_shapes() {
        let mut b = GraphBuilder::new("s");
        b.input("x", vec![1, 3, 8, 8]);
        b.initializer("w", Tensor::zeros(vec![16, 3, 3, 3]));
        b.node(
            "Conv",
            &["x", "w"],
            &["c"],
            &[("kernel_shape", vec![3i64, 3].into()), ("pads", vec![1i64, 1, 1, 1].into())],
        );
        b.node("MaxPool", &["c"], &["p"], &[("kernel_shape", vec![2i64, 2].into())]);
        b.output_unknown("p");
        let mut g = b.finish().unwrap();
        assert_eq!(g.tensor_shape("c"), None);
        assert!(infer_shapes(&mut g).unwrap());
        assert_eq!(g.tensor_shape("c"), Some(vec![1, 16, 8, 8]));
        assert_eq!(g.tensor_shape("p"), Some(vec![1, 16, 4, 4]));
        // idempotent
        assert!(!infer_shapes(&mut g).unwrap());
    }

    #[test]
    fn requires_declared_input_shape() {
        let mut g = ModelGraph::new("noshape");
        g.inputs.push(crate::ir::ValueInfo::unknown("x"));
        assert!(infer_shapes(&mut g).is_err());
    }
}

//! QONNX → QCDQ lowering (paper §IV).
//!
//! Each `Quant` node becomes `QuantizeLinear → Clip → DequantizeLinear`,
//! with the `Clip` carrying the sub-8-bit integer bounds of Eqs. 2–3. The
//! resulting graph uses only standard ONNX operators and therefore runs on
//! stock 8-bit backends — the paper's backward-compatibility claim, which
//! `rust/tests/lowering.rs` demonstrates by executing the lowered graph
//! with `ExecOptions::standard_onnx_only`.
//!
//! The QCDQ restrictions from Table I are *enforced* here, and each
//! refusal is one of the ✗ cells:
//! * bit widths above 8 → unrepresentable (no arbitrary precision);
//! * non-`ROUND` rounding modes → unrepresentable (QuantizeLinear rounds
//!   half-to-even, period);
//! * channel-wise bit width → unrepresentable (`Clip` bounds are scalars);
//! * `BipolarQuant` / `Trunc` → unrepresentable.

use super::quant_params_static;
use crate::ir::{ModelGraph, Node};
use anyhow::{bail, ensure, Result};

/// Lower all QONNX-dialect nodes to QCDQ. Fails loudly on anything QCDQ
/// cannot express (see module docs).
pub fn lower_to_qcdq(graph: &mut ModelGraph) -> Result<bool> {
    let mut changed = false;
    loop {
        let Some(i) = graph
            .nodes
            .iter()
            .position(|n| matches!(n.op_type.as_str(), "Quant" | "BipolarQuant" | "Trunc"))
        else {
            graph.sort_topologically()?;
            if changed {
                graph.validate()?;
            }
            return Ok(changed);
        };
        let node = graph.nodes[i].clone();
        match node.op_type.as_str() {
            "Quant" => lower_quant(graph, i, &node)?,
            other => bail!(
                "QCDQ cannot represent '{other}' (node '{}'): \
                 no standard-ONNX equivalent exists",
                node.name
            ),
        }
        changed = true;
    }
}

fn lower_quant(graph: &mut ModelGraph, idx: usize, node: &Node) -> Result<()> {
    let p = quant_params_static(graph, node)?;
    ensure!(
        p.bit_width <= 8.0,
        "QCDQ cannot represent {}-bit quantization (node '{}'): \
         QuantizeLinear is limited to 8-bit outputs",
        p.bit_width,
        node.name
    );
    // Fractional widths (paper §V, e.g. 7.5 bits) produce non-integer
    // Clip bounds like -90.5 that no int8 container represents — a ✗
    // cell of Table I, same as >8-bit precision.
    ensure!(
        p.bit_width.fract() == 0.0,
        "QCDQ cannot represent fractional {}-bit quantization (node '{}'): \
         integer-container Clip bounds only",
        p.bit_width,
        node.name
    );
    ensure!(
        p.rounding_mode == "ROUND",
        "QCDQ cannot represent rounding mode '{}' (node '{}')",
        p.rounding_mode,
        node.name
    );
    ensure!(
        p.zero_point.fract() == 0.0,
        "QCDQ needs an integer zero point, got {} (node '{}')",
        p.zero_point,
        node.name
    );
    let (lo, hi) = crate::ops::quant::quant_bounds(p.signed, p.narrow, p.bit_width);

    let x = node.inputs[0].clone();
    let scale = node.inputs[1].clone();
    let zeropt = node.inputs[2].clone();
    let y = node.outputs[0].clone();
    let q_name = graph.fresh_name(&format!("{y}_q"));
    let base = &node.name;

    let qnode = Node::new("QuantizeLinear", &[&x, &scale, &zeropt], &[&q_name])
        .with_name(&format!("{base}_quantize"))
        .with_attr("signed", p.signed);

    // full-range 8-bit with no narrowing needs no Clip (plain QDQ)
    let needs_clip = p.bit_width < 8.0 || p.narrow;
    let dq_input = if needs_clip {
        let c_name = graph.fresh_name(&format!("{y}_clip"));
        let lo_name = graph.fresh_name(&format!("{y}_clip_lo"));
        let hi_name = graph.fresh_name(&format!("{y}_clip_hi"));
        graph.initializers.insert(lo_name.clone(), crate::tensor::Tensor::scalar(lo as f32));
        graph.initializers.insert(hi_name.clone(), crate::tensor::Tensor::scalar(hi as f32));
        let cnode = Node::new("Clip", &[&q_name, &lo_name, &hi_name], &[&c_name])
            .with_name(&format!("{base}_clip"));
        graph.nodes.push(cnode);
        c_name
    } else {
        q_name.clone()
    };
    let dnode = Node::new("DequantizeLinear", &[&dq_input, &scale, &zeropt], &[&y])
        .with_name(&format!("{base}_dequantize"));

    graph.nodes.remove(idx);
    graph.nodes.push(qnode);
    graph.nodes.push(dnode);
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::exec::{execute_simple, execute_with, ExecOptions};
    use crate::ir::GraphBuilder;
    use crate::tensor::Tensor;
    use std::collections::BTreeMap;

    fn quant_graph(bw: f32, signed: bool, narrow: bool, mode: &str) -> ModelGraph {
        let mut b = GraphBuilder::new("q");
        b.input("x", vec![1, 16]);
        b.quant("x", "y", 0.25, 0.0, bw, signed, narrow, mode);
        b.output("y", vec![1, 16]);
        b.finish().unwrap()
    }

    fn ramp() -> Tensor {
        Tensor::new(vec![1, 16], (0..16).map(|v| (v as f32 - 8.0) * 0.4).collect())
    }

    #[test]
    fn qcdq_matches_quant_int4() {
        let g0 = quant_graph(4.0, true, false, "ROUND");
        let mut g1 = g0.clone();
        assert!(lower_to_qcdq(&mut g1).unwrap());
        assert_eq!(g1.op_histogram()["QuantizeLinear"], 1);
        assert_eq!(g1.op_histogram()["Clip"], 1);
        assert_eq!(g1.op_histogram()["DequantizeLinear"], 1);
        let x = ramp();
        assert_eq!(execute_simple(&g0, &x).unwrap(), execute_simple(&g1, &x).unwrap());
    }

    #[test]
    fn qcdq_narrow_uses_clip_at_8bit() {
        let mut g = quant_graph(8.0, true, true, "ROUND");
        lower_to_qcdq(&mut g).unwrap();
        assert!(g.op_histogram().contains_key("Clip"));
        assert_eq!(g.initializers.values().filter(|t| t.numel() == 1).count() >= 2, true);
    }

    #[test]
    fn qcdq_8bit_full_range_is_plain_qdq() {
        let g0 = quant_graph(8.0, true, false, "ROUND");
        let mut g1 = g0.clone();
        lower_to_qcdq(&mut g1).unwrap();
        assert!(!g1.op_histogram().contains_key("Clip"));
        let x = ramp();
        assert_eq!(execute_simple(&g0, &x).unwrap(), execute_simple(&g1, &x).unwrap());
    }

    #[test]
    fn lowered_graph_runs_on_standard_backend() {
        // the paper's §IV claim, end to end
        let mut g = quant_graph(3.0, false, false, "ROUND");
        lower_to_qcdq(&mut g).unwrap();
        let mut m = BTreeMap::new();
        m.insert("x".to_string(), ramp());
        let opts = ExecOptions { standard_onnx_only: true, ..Default::default() };
        execute_with(&g, &m, &opts).unwrap();
    }

    #[test]
    fn rejects_above_8_bits() {
        let mut g = quant_graph(9.0, true, false, "ROUND");
        let err = lower_to_qcdq(&mut g).unwrap_err();
        assert!(err.to_string().contains("8-bit"));
    }

    #[test]
    fn rejects_fractional_bit_width_but_native_exec_accepts() {
        // nb = 7.5 (paper §V) executes natively on the QONNX backend ...
        let g0 = quant_graph(7.5, true, false, "ROUND");
        let y = execute_simple(&g0, &ramp()).unwrap();
        assert_eq!(y.shape(), &[1, 16]);
        // ... but QCDQ has no int8 container for Clip bounds like -90.5
        let mut g1 = g0.clone();
        let err = lower_to_qcdq(&mut g1).unwrap_err().to_string();
        assert!(err.contains("fractional"), "{err}");
    }

    #[test]
    fn rejects_rounding_variants() {
        let mut g = quant_graph(4.0, true, false, "FLOOR");
        assert!(lower_to_qcdq(&mut g).is_err());
    }

    #[test]
    fn rejects_bipolar() {
        let mut b = GraphBuilder::new("bp");
        b.input("x", vec![1, 4]);
        b.bipolar_quant("x", "y", 1.0);
        b.output("y", vec![1, 4]);
        let mut g = b.finish().unwrap();
        assert!(lower_to_qcdq(&mut g).is_err());
    }
}

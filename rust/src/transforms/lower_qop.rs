//! QONNX → quantized-operator-format-with-clipping lowering (paper §IV).
//!
//! Pattern-matches the canonical quantized linear layer
//!
//! ```text
//! Quant(act) ──► Conv/MatMul (weights = Quant(W init)) ──► Quant(out)
//! ```
//!
//! and emits `QLinearConv`/`QLinearMatMul` followed by a `Clip` that
//! narrows the fused 8-bit output requantization to the target bit width.
//! The restrictions are exactly Table I's ✗ column for this format:
//! weights-only quantization, high-precision outputs, rounding variants
//! and >8-bit precision are all refused.

use super::{quant_params_static, QuantParams};
use crate::ir::{ModelGraph, Node};
use crate::ops::quant::quant_bounds;
use crate::tensor::Tensor;
use anyhow::{bail, ensure, Context, Result};

fn check_q8(p: &QuantParams, what: &str, node: &str) -> Result<()> {
    ensure!(
        p.bit_width <= 8.0,
        "quantized-op format cannot represent {}-bit {what} (node '{node}')",
        p.bit_width
    );
    ensure!(
        p.rounding_mode == "ROUND",
        "quantized-op format cannot represent rounding mode '{}' ({what}, node '{node}')",
        p.rounding_mode
    );
    Ok(())
}

/// Lower matched patterns. Any remaining QONNX node afterwards is an
/// error: this format cannot express weights-only or activation-only
/// quantization, so the whole graph must match.
pub fn lower_to_qop_clip(graph: &mut ModelGraph) -> Result<bool> {
    let mut changed = false;
    'outer: loop {
        graph.sort_topologically()?;
        for li in 0..graph.nodes.len() {
            let lin = graph.nodes[li].clone();
            if !matches!(lin.op_type.as_str(), "Conv" | "MatMul") {
                continue;
            }
            // act input must come from a Quant
            let Some(aq_idx) = graph.producer(&lin.inputs[0]) else { continue };
            if graph.nodes[aq_idx].op_type != "Quant" {
                continue;
            }
            // weight input must be a Quant over an initializer
            let Some(wq_idx) = graph.producer(&lin.inputs[1]) else { continue };
            if graph.nodes[wq_idx].op_type != "Quant" {
                continue;
            }
            // output must feed exactly one Quant
            let out_cons = graph.consumers(&lin.outputs[0]);
            if out_cons.len() != 1 || graph.nodes[out_cons[0]].op_type != "Quant" {
                continue;
            }
            let oq_idx = out_cons[0];

            let aq = graph.nodes[aq_idx].clone();
            let wq = graph.nodes[wq_idx].clone();
            let oq = graph.nodes[oq_idx].clone();
            let ap = quant_params_static(graph, &aq)?;
            let wp = quant_params_static(graph, &wq)?;
            let op = quant_params_static(graph, &oq)?;
            check_q8(&ap, "activation quantization", &aq.name)?;
            check_q8(&wp, "weight quantization", &wq.name)?;
            check_q8(&op, "output quantization", &oq.name)?;
            ensure!(
                wp.zero_point == 0.0,
                "quantized-op format expects symmetric weights (zero point 0), node '{}'",
                wq.name
            );
            let w_init = graph
                .initializer(&wq.inputs[0])
                .with_context(|| format!("weight Quant '{}' input is not an initializer", wq.name))?
                .clone();

            // pre-quantized integer weight tensor: w_int = round(W/s) clamped
            let (wlo, whi) = quant_bounds(wp.signed, wp.narrow, wp.bit_width);
            let w_int = w_init.map(|v| {
                crate::ops::quant::round_half_even(f64::from(v) / f64::from(wp.scale))
                    .clamp(wlo, whi) as f32
            })?;

            // names
    let y = oq.outputs[0].clone();
            let x_src = aq.inputs[0].clone();
            let pre = graph.fresh_name(&format!("{y}_xq8"));
            let acc = graph.fresh_name(&format!("{y}_acc8"));
            let base = lin.name.clone();
            let mk_scalar = |graph: &mut ModelGraph, tag: &str, v: f32| -> String {
                let n = graph.fresh_name(&format!("{base}_{tag}"));
                graph.initializers.insert(n.clone(), Tensor::scalar(v));
                n
            };
            // input is quantized by the *previous* layer in this format, so
            // emit an explicit QuantizeLinear+Clip producing int8 activations
            let xs = mk_scalar(graph, "x_scale", ap.scale);
            let xz = mk_scalar(graph, "x_zp", ap.zero_point);
            let ws_name = mk_scalar(graph, "w_scale", wp.scale);
            let wz = mk_scalar(graph, "w_zp", 0.0);
            let ys = mk_scalar(graph, "y_scale", op.scale);
            let yz = mk_scalar(graph, "y_zp", op.zero_point);
            let w_name = graph.fresh_name(&format!("{base}_w_int"));
            graph.initializers.insert(w_name.clone(), w_int);

            let mut new_nodes: Vec<Node> = Vec::new();
            let qx = Node::new("QuantizeLinear", &[&x_src, &xs, &xz], &[&pre])
                .with_name(format!("{base}_quantize_x").as_str())
                .with_attr("signed", ap.signed);
            new_nodes.push(qx);
            // clip activation to its sub-8-bit range (operator format w/ clipping)
            let (alo, ahi) = quant_bounds(ap.signed, ap.narrow, ap.bit_width);
            let xq_in = if ap.bit_width < 8.0 || ap.narrow {
                let lo = mk_scalar(graph, "x_lo", alo as f32);
                let hi = mk_scalar(graph, "x_hi", ahi as f32);
                let cn = graph.fresh_name(&format!("{y}_xq8c"));
                new_nodes.push(
                    Node::new("Clip", &[&pre, &lo, &hi], &[&cn]).with_name(format!("{base}_clip_x").as_str()),
                );
                cn
            } else {
                pre.clone()
            };

            let qlin_op = if lin.op_type == "Conv" { "QLinearConv" } else { "QLinearMatMul" };
            let mut qlin = Node::new(
                qlin_op,
                &[&xq_in, &xs, &xz, &w_name, &ws_name, &wz, &ys, &yz],
                &[&acc],
            )
            .with_name(format!("{base}_qlinear").as_str())
            .with_attr("signed", op.signed);
            if lin.op_type == "Conv" {
                for key in ["kernel_shape", "strides", "pads", "group", "dilations"] {
                    if let Some(a) = lin.attrs.get(key) {
                        qlin.attrs.insert(key.to_string(), a.clone());
                    }
                }
            }
            new_nodes.push(qlin);
            // clip fused 8-bit output down to the target precision
            let (olo, ohi) = quant_bounds(op.signed, op.narrow, op.bit_width);
            let qy = if op.bit_width < 8.0 || op.narrow {
                let lo = mk_scalar(graph, "y_lo", olo as f32);
                let hi = mk_scalar(graph, "y_hi", ohi as f32);
                let cn = graph.fresh_name(&format!("{y}_acc8c"));
                new_nodes.push(
                    Node::new("Clip", &[&acc, &lo, &hi], &[&cn]).with_name(format!("{base}_clip_y").as_str()),
                );
                cn
            } else {
                acc.clone()
            };
            // final dequantize so downstream float consumers still work
            new_nodes.push(
                Node::new("DequantizeLinear", &[&qy, &ys, &yz], &[&y])
                    .with_name(format!("{base}_dequantize_y").as_str()),
            );

            let mut to_remove = vec![li, aq_idx, wq_idx, oq_idx];
            to_remove.sort_unstable();
            for i in to_remove.into_iter().rev() {
                graph.nodes.remove(i);
            }
            graph.nodes.extend(new_nodes);
            super::remove_dead_nodes(graph)?;
            changed = true;
            continue 'outer;
        }
        // no more matches: any surviving QONNX node is unrepresentable
        if let Some(n) = graph
            .nodes
            .iter()
            .find(|n| matches!(n.op_type.as_str(), "Quant" | "BipolarQuant" | "Trunc"))
        {
            bail!(
                "quantized-op format cannot represent node '{}' ({}): \
                 only fully-quantized Conv/MatMul patterns are expressible \
                 (weights-only or activation-only quantization is a Table I ✗)",
                n.name,
                n.op_type
            );
        }
        graph.sort_topologically()?;
        if changed {
            graph.validate()?;
        }
        return Ok(changed);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::exec::{execute_simple, execute_with, ExecOptions};
    use crate::ir::GraphBuilder;
    use std::collections::BTreeMap;

    /// Quant(x) -> MatMul(Quant(W)) -> Quant(out)
    fn qlinear_pattern() -> ModelGraph {
        let mut b = GraphBuilder::new("p");
        b.input("x", vec![1, 4]);
        b.quant("x", "xq", 0.1, 0.0, 8.0, true, false, "ROUND");
        b.initializer("w", Tensor::new(vec![4, 2], vec![0.5, -0.25, 0.75, 0.1, -0.6, 0.3, 0.2, -0.4]));
        b.quant("w", "wq", 0.05, 0.0, 4.0, true, false, "ROUND");
        b.node("MatMul", &["xq", "wq"], &["mm"], &[]);
        b.quant("mm", "y", 0.2, 0.0, 8.0, true, false, "ROUND");
        b.output("y", vec![1, 2]);
        b.finish().unwrap()
    }

    #[test]
    fn lowers_pattern_to_qlinear_matmul() {
        let g0 = qlinear_pattern();
        let mut g1 = g0.clone();
        assert!(lower_to_qop_clip(&mut g1).unwrap());
        let h = g1.op_histogram();
        assert!(h.contains_key("QLinearMatMul"));
        assert!(!h.contains_key("Quant"));
        // weight initializer is now integer-valued
        let qlin = g1.nodes.iter().find(|n| n.op_type == "QLinearMatMul").unwrap();
        let w = &g1.initializers[&qlin.inputs[3]];
        assert!(w.as_f32().unwrap().iter().all(|v| v.fract() == 0.0));

        // runs on a standard-only backend
        let mut m = BTreeMap::new();
        m.insert("x".to_string(), Tensor::new(vec![1, 4], vec![0.3, -0.2, 0.5, 0.1]));
        let opts = ExecOptions { standard_onnx_only: true, ..Default::default() };
        execute_with(&g1, &m, &opts).unwrap();
    }

    #[test]
    fn lowered_semantics_close_to_qonnx() {
        // requantization reorders rounding, so allow one output ULP
        let g0 = qlinear_pattern();
        let mut g1 = g0.clone();
        lower_to_qop_clip(&mut g1).unwrap();
        let x = Tensor::new(vec![1, 4], vec![0.3, -0.2, 0.5, 0.1]);
        let y0 = execute_simple(&g0, &x).unwrap();
        let y1 = execute_simple(&g1, &x).unwrap();
        for (a, b) in y0.as_f32().unwrap().iter().zip(y1.as_f32().unwrap()) {
            assert!((a - b).abs() <= 0.2 + 1e-6, "{a} vs {b}");
        }
    }

    #[test]
    fn rejects_weights_only_quantization() {
        // weights-only quantization: a Table I ✗ for this format
        let mut b = GraphBuilder::new("wo");
        b.input("x", vec![1, 4]);
        b.initializer("w", Tensor::zeros(vec![4, 2]));
        b.quant("w", "wq", 0.05, 0.0, 4.0, true, false, "ROUND");
        b.node("MatMul", &["x", "wq"], &["y"], &[]);
        b.output("y", vec![1, 2]);
        let mut g = b.finish().unwrap();
        assert!(lower_to_qop_clip(&mut g).is_err());
    }
}

//! Graph transformation passes — the Rust analog of the paper's "software
//! utilities for working with QONNX" (§V) plus the backend ingestion flows
//! (§VI).
//!
//! Every pass is a function `&mut ModelGraph -> Result<bool>` returning
//! whether the graph changed; [`cleanup`] composes the standard pipeline
//! (shape inference → constant folding → identity removal → dead-code
//! elimination → unique names), reproducing the Fig. 1 → Fig. 2 step.

mod channels_last;
mod cleanup;
mod finn_ingest;
mod fold_constants;
mod hls4ml_ingest;
mod infer_datatypes;
mod infer_shapes;
mod lower_qcdq;
mod lower_qop;
mod raise_qcdq;

pub use channels_last::to_channels_last;
pub use cleanup::{cleanup, give_unique_names, remove_dead_nodes, remove_identity};
pub use finn_ingest::{convert_to_finn, fold_weight_quants, quant_to_multithreshold, quant_to_thresholds};
pub use fold_constants::fold_constants;
pub use hls4ml_ingest::{hls4ml_ingest, propagate_dequant, quantize_constant_paths};
pub use infer_datatypes::{infer_datatypes, infer_ranges, ValueRange};
pub use infer_shapes::infer_shapes;
pub use lower_qcdq::lower_to_qcdq;
pub use lower_qop::lower_to_qop_clip;
pub use raise_qcdq::raise_qcdq_to_qonnx;

use crate::ir::{ModelGraph, Node};
use anyhow::{Context, Result};

/// Statically-resolved parameters of a `Quant` node whose scale /
/// zero-point / bit-width inputs are scalar initializers. Most lowering
/// passes require this form (dynamic quantization stays QONNX-only —
/// another Table I ✗ for the low-level formats).
#[derive(Debug, Clone)]
pub struct QuantParams {
    pub scale: f32,
    pub zero_point: f32,
    pub bit_width: f64,
    pub signed: bool,
    pub narrow: bool,
    pub rounding_mode: String,
}

/// Extract static scalar quantization parameters from a `Quant` node.
pub fn quant_params_static(graph: &ModelGraph, node: &Node) -> Result<QuantParams> {
    anyhow::ensure!(node.op_type == "Quant", "not a Quant node: {}", node.op_type);
    let get = |idx: usize, what: &str| -> Result<f32> {
        let name = &node.inputs[idx];
        let t = graph
            .initializer(name)
            .with_context(|| format!("Quant '{}' {what} '{name}' is not a static initializer", node.name))?;
        anyhow::ensure!(t.numel() == 1, "Quant '{}' {what} is not scalar (shape {:?})", node.name, t.shape());
        t.scalar_value()
    };
    Ok(QuantParams {
        scale: get(1, "scale")?,
        zero_point: get(2, "zero_point")?,
        bit_width: f64::from(get(3, "bit_width")?),
        signed: node.attr_int_or("signed", 1) != 0,
        narrow: node.attr_int_or("narrow", 0) != 0,
        rounding_mode: node.attr_str_or("rounding_mode", "ROUND"),
    })
}

/// Run a pass to fixpoint (bounded to avoid ping-ponging passes looping
/// forever on a bug).
pub fn fixpoint(graph: &mut ModelGraph, pass: impl Fn(&mut ModelGraph) -> Result<bool>) -> Result<()> {
    for _ in 0..100 {
        if !pass(graph)? {
            return Ok(());
        }
    }
    anyhow::bail!("pass did not converge within 100 iterations on graph '{}'", graph.name)
}

//! QCDQ → QONNX raising: fuse `QuantizeLinear [→ Clip] → DequantizeLinear`
//! triples back into a single `Quant` node.
//!
//! This is the ingestion direction: models exported by QCDQ-producing
//! tools (e.g. Brevitas' QCDQ export, §VI-B) become first-class QONNX, with
//! the bit width recovered from the `Clip` bounds.

use crate::ir::{ModelGraph, Node, DOMAIN_QONNX};
use crate::tensor::Tensor;
use anyhow::{ensure, Result};

/// Recover (bit_width, signed, narrow) from integer clip bounds.
///
/// `[-2^(b-1), 2^(b-1)-1]` → signed b-bit; `[-2^(b-1)+1, 2^(b-1)-1]` →
/// signed narrow; `[0, 2^b-1]` → unsigned; `[0, 2^b-2]` → unsigned narrow.
pub fn bounds_to_quant_params(lo: f64, hi: f64) -> Option<(f64, bool, bool)> {
    if lo == 0.0 {
        // unsigned: hi = 2^b - 1 - narrow
        for narrow in [false, true] {
            let b = ((hi + 1.0 + if narrow { 1.0 } else { 0.0 }) as f64).log2();
            if b.fract() == 0.0 && b >= 1.0 {
                return Some((b, false, narrow));
            }
        }
        None
    } else if lo < 0.0 {
        for narrow in [false, true] {
            let b = (-lo + if narrow { 1.0 } else { 0.0 }).log2() + 1.0;
            if b.fract() == 0.0 && b >= 2.0 && hi == 2f64.powf(b - 1.0) - 1.0 {
                return Some((b, true, narrow));
            }
        }
        None
    } else {
        None
    }
}

/// Fuse all QCDQ patterns into `Quant` nodes. Returns true if changed.
pub fn raise_qcdq_to_qonnx(graph: &mut ModelGraph) -> Result<bool> {
    let mut changed = false;
    'outer: loop {
        graph.sort_topologically()?;
        for qi in 0..graph.nodes.len() {
            if graph.nodes[qi].op_type != "QuantizeLinear" {
                continue;
            }
            let q = graph.nodes[qi].clone();
            let q_out = q.outputs[0].clone();
            let consumers = graph.consumers(&q_out);
            if consumers.len() != 1 || graph.is_output(&q_out) {
                continue;
            }
            // optional Clip
            let (clip_idx, dq_idx, lo_hi) = match graph.nodes[consumers[0]].op_type.as_str() {
                "Clip" => {
                    let c = graph.nodes[consumers[0]].clone();
                    let lo = c.inputs.get(1).and_then(|n| graph.initializer(n)).and_then(|t| t.scalar_value().ok());
                    let hi = c.inputs.get(2).and_then(|n| graph.initializer(n)).and_then(|t| t.scalar_value().ok());
                    let (Some(lo), Some(hi)) = (lo, hi) else { continue };
                    let c_out = c.outputs[0].clone();
                    let dqs = graph.consumers(&c_out);
                    if dqs.len() != 1 || graph.is_output(&c_out) || graph.nodes[dqs[0]].op_type != "DequantizeLinear" {
                        continue;
                    }
                    (Some(consumers[0]), dqs[0], Some((f64::from(lo), f64::from(hi))))
                }
                "DequantizeLinear" => (None, consumers[0], None),
                _ => continue,
            };
            let dq = graph.nodes[dq_idx].clone();
            // scale / zero point must match between Q and DQ
            ensure!(
                q.inputs[1] == dq.inputs[1]
                    && q.inputs.get(2).map(|s| s.as_str()).unwrap_or("")
                        == dq.inputs.get(2).map(|s| s.as_str()).unwrap_or(""),
                "QCDQ fuse: Q/DQ scale or zero-point mismatch at '{}'",
                q.name
            );
            let q_signed = q.attr_int_or("signed", 0) != 0;
            let (bw, signed, narrow) = match lo_hi {
                Some((lo, hi)) => match bounds_to_quant_params(lo, hi) {
                    Some(p) => p,
                    None => continue, // non-integer-power bounds: leave as-is
                },
                None => (8.0, q_signed, false),
            };
            ensure!(
                signed == q_signed || lo_hi.is_none(),
                "QCDQ fuse: clip bounds signedness disagrees with QuantizeLinear at '{}'",
                q.name
            );

            // build the Quant node
            let y = dq.outputs[0].clone();
            let bw_name = graph.fresh_name(&format!("{y}_bitwidth"));
            graph.initializers.insert(bw_name.clone(), Tensor::scalar(bw as f32));
            let zeropt = if q.inputs.len() > 2 {
                q.inputs[2].clone()
            } else {
                let z = graph.fresh_name(&format!("{y}_zeropt"));
                graph.initializers.insert(z.clone(), Tensor::scalar(0.0));
                z
            };
            let quant = Node::new("Quant", &[&q.inputs[0], &q.inputs[1], &zeropt, &bw_name], &[&y])
                .with_domain(DOMAIN_QONNX)
                .with_name(&format!("{}_raised", q.name))
                .with_attr("signed", signed)
                .with_attr("narrow", narrow)
                .with_attr("rounding_mode", "ROUND");

            // remove DQ, Clip, Q (descending index order)
            let mut to_remove = vec![qi, dq_idx];
            if let Some(ci) = clip_idx {
                to_remove.push(ci);
            }
            to_remove.sort_unstable();
            for i in to_remove.into_iter().rev() {
                graph.nodes.remove(i);
            }
            graph.nodes.push(quant);
            changed = true;
            continue 'outer;
        }
        if changed {
            super::remove_dead_nodes(graph)?;
            graph.sort_topologically()?;
            graph.validate()?;
        }
        return Ok(changed);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::exec::execute_simple;
    use crate::ir::GraphBuilder;
    use crate::transforms::lower_to_qcdq;

    #[test]
    fn bounds_recovery() {
        assert_eq!(bounds_to_quant_params(-8.0, 7.0), Some((4.0, true, false)));
        assert_eq!(bounds_to_quant_params(-7.0, 7.0), Some((4.0, true, true)));
        assert_eq!(bounds_to_quant_params(0.0, 15.0), Some((4.0, false, false)));
        assert_eq!(bounds_to_quant_params(0.0, 14.0), Some((4.0, false, true)));
        assert_eq!(bounds_to_quant_params(-128.0, 127.0), Some((8.0, true, false)));
        assert_eq!(bounds_to_quant_params(-5.0, 5.0), None);
    }

    #[test]
    fn roundtrip_quant_to_qcdq_and_back() {
        let mut b = GraphBuilder::new("rt");
        b.input("x", vec![1, 8]);
        b.quant("x", "y", 0.25, 0.0, 5.0, true, false, "ROUND");
        b.output("y", vec![1, 8]);
        let g0 = b.finish().unwrap();
        let mut g1 = g0.clone();
        lower_to_qcdq(&mut g1).unwrap();
        assert!(!g1.op_histogram().contains_key("Quant"));
        assert!(raise_qcdq_to_qonnx(&mut g1).unwrap());
        assert_eq!(g1.op_histogram()["Quant"], 1);
        let q = g1.nodes.iter().find(|n| n.op_type == "Quant").unwrap();
        assert_eq!(q.attr_int_or("signed", -1), 1);
        assert_eq!(q.attr_int_or("narrow", -1), 0);

        let x = crate::tensor::Tensor::new(vec![1, 8], (0..8).map(|v| v as f32 * 0.9 - 3.0).collect());
        assert_eq!(execute_simple(&g0, &x).unwrap(), execute_simple(&g1, &x).unwrap());
    }

    #[test]
    fn raises_plain_qdq_as_8bit() {
        let mut b = GraphBuilder::new("qdq");
        b.input("x", vec![1, 4]);
        b.scalar("s", 0.5);
        b.scalar("z", 0.0);
        b.node("QuantizeLinear", &["x", "s", "z"], &["q"], &[("signed", 1i64.into())]);
        b.node("DequantizeLinear", &["q", "s", "z"], &["y"], &[]);
        b.output("y", vec![1, 4]);
        let mut g = b.finish().unwrap();
        assert!(raise_qcdq_to_qonnx(&mut g).unwrap());
        let q = g.nodes.iter().find(|n| n.op_type == "Quant").unwrap();
        assert_eq!(g.initializers[&q.inputs[3]].scalar_value().unwrap(), 8.0);
    }

    #[test]
    fn leaves_mismatched_scales_alone() {
        let mut b = GraphBuilder::new("mm");
        b.input("x", vec![1, 4]);
        b.scalar("s1", 0.5);
        b.scalar("s2", 0.25);
        b.scalar("z", 0.0);
        b.node("QuantizeLinear", &["x", "s1", "z"], &["q"], &[]);
        b.node("DequantizeLinear", &["q", "s2", "z"], &["y"], &[]);
        b.output("y", vec![1, 4]);
        let mut g = b.finish().unwrap();
        assert!(raise_qcdq_to_qonnx(&mut g).is_err());
    }
}

//! Arithmetic-safety proofs for the quantized tier, re-established from
//! scratch.
//!
//! The exactness contract of [`crate::plan::qkernel`] rests on three
//! compile-time claims: the input lives on a proven integer grid, every
//! `i32` accumulator magnitude stays below `2^24` (so the f32 container
//! holds it exactly), and threshold rows are per-channel sorted (so the
//! binary search replays the generic op's linear count). The kernels
//! *trust* the claims at run time — resident-integer inputs skip
//! per-element re-validation entirely.
//!
//! This pass re-derives each claim without executing:
//!
//! * the accumulator bound `|x| · |w| · k + |c| < 2^24` is re-computed
//!   from the kernel's claimed input range and its packed weights
//!   ([`Code::AccumulatorUnbounded`]);
//! * the claimed range itself is re-derived from the source graph via
//!   [`infer_ranges`] and checked for containment — a claimed range
//!   narrower than the provable one would let out-of-grid values into
//!   unvalidated integer paths ([`Code::InputRangeMismatch`]);
//! * threshold rows (fused `QThreshold` epilogues and standalone
//!   [`ThresholdKernel`]s) are re-checked: shape, per-channel
//!   monotonicity, the f32-exact window, and the channel count against
//!   the producing kernel's output channels;
//! * the chosen output container must hold the proven level grid
//!   ([`Code::GridOverflowsContainer`]): an integer container under
//!   levels that only fit a wider one silently truncates.

use super::{Code, Location, VerifyReport};
use crate::ir::ModelGraph;
use crate::plan::qkernel::{QThreshold, ThresholdKernel};
use crate::plan::{CompiledKernel, ExecutionPlan};
use crate::tensor::{DType, F32_EXACT_INT_LIMIT};
use crate::transforms::{infer_ranges, ValueRange};
use std::collections::BTreeMap;

pub(super) fn check(plan: &ExecutionPlan<'_>, graph: &ModelGraph, r: &mut VerifyReport) {
    let any_quant = plan.steps.iter().any(|s| {
        matches!(
            s.kernel,
            CompiledKernel::QConv(_)
                | CompiledKernel::QGemm(_)
                | CompiledKernel::QMatMul(_)
                | CompiledKernel::Threshold(_)
        )
    });
    if !any_quant {
        return;
    }
    // Re-derive the value-range proofs the compiler's quantized tier
    // rested on. Same call, same graph — deterministic, so a correct
    // plan's claimed ranges are bit-equal to these.
    let ranges: BTreeMap<String, ValueRange> = infer_ranges(graph).unwrap_or_default();

    for (si, step) in plan.steps.iter().enumerate() {
        let loc = Location::Step(si);
        let node = &graph.nodes[step.node_idx];
        let data_input = node.inputs.first().map(String::as_str).unwrap_or("");
        match &step.kernel {
            CompiledKernel::QConv(qc) => {
                let (w_abs, k) = qc.acc_terms();
                check_quant_input(
                    r, loc, &ranges, data_input, qc.input_range(), w_abs, k, 0.0,
                );
                grid_fit(r, loc, qc.out_dtype(), qc.preferred_out_dtype());
                if let Some(qt) = qc.epilogue() {
                    check_qthreshold(r, loc, qt, qc.out_channels());
                }
            }
            CompiledKernel::QGemm(qg) => {
                let (w_abs, k) = qg.acc_terms();
                check_quant_input(
                    r, loc, &ranges, data_input, qg.input_range(), w_abs, k, qg.bias_abs(),
                );
                grid_fit(r, loc, qg.out_dtype(), qg.preferred_out_dtype());
                if let Some(qt) = qg.epilogue() {
                    check_qthreshold(r, loc, qt, qg.out_channels());
                }
            }
            CompiledKernel::QMatMul(qm) => {
                let (w_abs, k) = qm.acc_terms();
                check_quant_input(
                    r, loc, &ranges, data_input, qm.input_range(), w_abs, k, 0.0,
                );
                grid_fit(r, loc, qm.out_dtype(), qm.preferred_out_dtype());
                if let Some(qt) = qm.epilogue() {
                    check_qthreshold(r, loc, qt, qm.out_channels());
                }
            }
            CompiledKernel::Threshold(tk) => {
                check_threshold_kernel(r, loc, tk);
                grid_fit(r, loc, tk.out_dtype(), tk.preferred_out_dtype());
            }
            _ => {}
        }
    }
}

/// Re-check a quantized kernel's claimed input range: it must be a
/// finite integral interval, the accumulator bound must hold under it,
/// and it must contain the range provable from the source graph.
#[allow(clippy::too_many_arguments)]
fn check_quant_input(
    r: &mut VerifyReport,
    loc: Location,
    ranges: &BTreeMap<String, ValueRange>,
    data_input: &str,
    claimed: (f64, f64),
    w_abs: f64,
    k: usize,
    bias_abs: f64,
) {
    let (lo, hi) = claimed;
    let usable =
        lo.is_finite() && hi.is_finite() && lo.fract() == 0.0 && hi.fract() == 0.0 && lo <= hi;
    if !usable {
        r.error(
            Code::AccumulatorUnbounded,
            loc,
            format!(
                "claimed input range [{lo}, {hi}] is not a finite integral interval — \
                 no accumulator bound can rest on it"
            ),
        );
    } else {
        let in_abs = lo.abs().max(hi.abs());
        let bound = in_abs * w_abs * k as f64 + bias_abs;
        if bound >= F32_EXACT_INT_LIMIT {
            r.error(
                Code::AccumulatorUnbounded,
                loc,
                format!(
                    "accumulator bound |x|≤{in_abs} · |w|≤{w_abs} · k={k} + |c|≤{bias_abs} \
                     = {bound} reaches 2^24 — the i32 → f32 emission is no longer exact"
                ),
            );
        }
    }
    match ranges.get(data_input) {
        None => r.warn(
            Code::UnprovenQuantInput,
            loc,
            format!(
                "no value range is derivable for quantized input '{data_input}' — the \
                 integer-grid claim cannot be re-established from the graph"
            ),
        ),
        Some(d) if !d.integral || !d.lo.is_finite() || !d.hi.is_finite() => r.error(
            Code::InputRangeMismatch,
            loc,
            format!(
                "derived range for '{data_input}' ([{}, {}], integral: {}) does not prove \
                 an integer grid",
                d.lo, d.hi, d.integral
            ),
        ),
        Some(d) if d.lo < lo || d.hi > hi => r.error(
            Code::InputRangeMismatch,
            loc,
            format!(
                "derived range [{}, {}] for '{data_input}' is not contained in the claimed \
                 [{lo}, {hi}] — runtime values could leave the validated grid",
                d.lo, d.hi
            ),
        ),
        Some(_) => {}
    }
}

/// The chosen output container must hold the proven level grid.
fn grid_fit(r: &mut VerifyReport, loc: Location, actual: DType, preferred: DType) {
    if actual == preferred || actual == DType::F32 {
        return; // exact choice, or the always-safe float container
    }
    if preferred == DType::I8 && actual == DType::I32 {
        r.warn(
            Code::GridOverflowsContainer,
            loc,
            format!(
                "output container {actual} is wider than the proven level grid needs \
                 ({preferred}) — correct, but wastes residency bandwidth"
            ),
        );
        return;
    }
    r.error(
        Code::GridOverflowsContainer,
        loc,
        format!(
            "output container {actual} cannot exactly hold the proven level grid \
             (narrowest exact container: {preferred})"
        ),
    );
}

/// Fused `MultiThreshold` epilogue: shape, channels, f32-exact window,
/// per-channel monotonicity.
fn check_qthreshold(r: &mut VerifyReport, loc: Location, qt: &QThreshold, out_channels: usize) {
    let (c, t) = (qt.channels(), qt.steps());
    if c != 1 && c != out_channels {
        r.error(
            Code::EpilogueChannelMismatch,
            loc,
            format!(
                "fused threshold has {c} channel rows but the kernel emits {out_channels} \
                 channels (1 or {out_channels} required)"
            ),
        );
    }
    let rows = qt.rows();
    if t == 0 || rows.len() != c * t {
        r.error(
            Code::ThresholdRowsMalformed,
            loc,
            format!("fused threshold rows: {} values for {c} channels × {t} steps", rows.len()),
        );
        return;
    }
    for (ci, row) in rows.chunks(t).enumerate() {
        if row.iter().any(|&v| f64::from(v).abs() >= F32_EXACT_INT_LIMIT) {
            r.error(
                Code::ThresholdRowsMalformed,
                loc,
                format!("fused threshold row {ci} leaves the f32-exact ±2^24 window"),
            );
        }
        if !row.windows(2).all(|w| w[0] <= w[1]) {
            r.error(
                Code::ThresholdRowsUnsorted,
                loc,
                format!(
                    "fused threshold row {ci} is not sorted — the binary search would \
                     diverge from the generic op's linear count"
                ),
            );
        }
    }
}

/// Standalone [`ThresholdKernel`]: shape, finiteness, monotonicity. The
/// rows live in the producer's f32 domain, so there is no ±2^24 window
/// requirement; non-finite rows are flagged as a warning (the compile
/// accepts a single-step NaN row, which the generic op also accepts —
/// it just thresholds nothing).
fn check_threshold_kernel(r: &mut VerifyReport, loc: Location, tk: &ThresholdKernel) {
    let (c, t) = (tk.channels(), tk.steps());
    let rows = tk.rows();
    if t == 0 || rows.len() != c * t {
        r.error(
            Code::ThresholdRowsMalformed,
            loc,
            format!("threshold rows: {} values for {c} channels × {t} steps", rows.len()),
        );
        return;
    }
    for (ci, row) in rows.chunks(t).enumerate() {
        if row.iter().any(|v| !v.is_finite()) {
            r.warn(
                Code::ThresholdRowsMalformed,
                loc,
                format!("threshold row {ci} contains non-finite values"),
            );
        }
        if !row.windows(2).all(|w| w[0] <= w[1]) {
            r.error(
                Code::ThresholdRowsUnsorted,
                loc,
                format!(
                    "threshold row {ci} is not sorted — the binary search would diverge \
                     from the generic op's linear count"
                ),
            );
        }
    }
}

//! Dtype-flow analysis: the slot-container table vs. what kernels
//! actually emit and accept.
//!
//! The residency pass (`plan/compile.rs::plan_residency`) negotiates an
//! integer container per runtime value and bakes the decision into both
//! the producing kernel (`set_out_dtype`) and the dtype-keyed slot
//! table. This pass re-checks the two views against each other, step by
//! step:
//!
//! * a kernel with a declared output container (`ThresholdKernel`,
//!   `QuantConv`/`QuantGemm`/`QuantMatMul`) must write to a slot of
//!   exactly that container;
//! * packed float kernels and generic ops emit f32 — an integer output
//!   slot under them is container confusion;
//! * dtype-polymorphic pass-throughs (`Reshape`/`Flatten`/`Squeeze`/
//!   `Unsqueeze`/`Relu`/plain-NCHW `MaxPool`, and the batch-symbolic
//!   `BatchReshape` kernel) re-emit their data input's container, so
//!   their output slot must match it (or f32, when residency is off);
//! * **integer-edge justification**: a slot is tracked as
//!   integer-resident when an integer-emitting kernel chain wrote it.
//!   Kernels with no integer path (packed float kernels, generic
//!   non-pass-through ops) reading such a slot is an error — the
//!   residency pass's backward f32-demand walk guarantees this never
//!   happens on a correct plan. Integer slots *not* rooted in such a
//!   chain (constant `i64` shape operands, integer initializers) are
//!   routine for generic ops and flagged only when a packed float
//!   kernel would choke on them at run time.

use super::{Code, Location, VerifyReport};
use crate::plan::{residency_passthrough, CompiledKernel, ExecutionPlan};
use crate::tensor::DType;

pub(super) fn check(plan: &ExecutionPlan<'_>, r: &mut VerifyReport) {
    let dt_of =
        |sl: u32| plan.slot_dtypes.get(sl as usize).copied().unwrap_or(DType::F32);
    // Slots whose current value was written by an integer-emitting
    // kernel chain (threshold / quantized kernels, propagated through
    // pass-throughs). Cleared on release so recycled slots don't carry
    // stale provenance.
    let mut int_resident = vec![false; plan.slot_count];

    for (si, step) in plan.steps.iter().enumerate() {
        let loc = Location::Step(si);
        let node = &plan.nodes[step.node_idx];
        let flagged =
            |f: &[bool], sl: u32| f.get(sl as usize).copied().unwrap_or(false);
        let in0 = step.inputs.first().map(|&sl| (dt_of(sl), flagged(&int_resident, sl)));

        // -- input-side rules ------------------------------------------
        match &step.kernel {
            CompiledKernel::Conv(_) | CompiledKernel::Gemm(_) | CompiledKernel::MatMul(_) => {
                for &sl in &step.inputs {
                    let dt = dt_of(sl);
                    if dt == DType::F32 {
                        continue;
                    }
                    if flagged(&int_resident, sl) {
                        r.error(
                            Code::KernelInputDtype,
                            loc,
                            format!(
                                "packed float kernel reads integer-resident slot {sl} ({dt}) \
                                 — the residency pass must demand f32 from its producers"
                            ),
                        );
                    } else {
                        r.warn(
                            Code::KernelInputDtype,
                            loc,
                            format!(
                                "packed float kernel reads a constant-rooted {dt} slot {sl}; \
                                 the kernel will reject it at run time"
                            ),
                        );
                    }
                }
            }
            CompiledKernel::Op(_) if !residency_passthrough(node) => {
                // generic ops routinely take integer *constants* (shape
                // operands); only a residency-produced integer edge is a
                // broken f32-demand proof
                for &sl in &step.inputs {
                    if flagged(&int_resident, sl) {
                        r.error(
                            Code::IntegerEdgeUnjustified,
                            loc,
                            format!(
                                "generic op '{}' reads integer-resident slot {sl} \
                                 ({}) but has no integer path — the backward f32-demand \
                                 walk should have kept this edge f32",
                                node.op_type,
                                dt_of(sl)
                            ),
                        );
                    }
                }
            }
            // quantized kernels, thresholds and pass-throughs are
            // container-polymorphic on the input side
            _ => {}
        }

        // -- release clears provenance (slot may be recycled) ----------
        for &sl in &step.release {
            if let Some(f) = int_resident.get_mut(sl as usize) {
                *f = false;
            }
        }

        // -- output-side rules -----------------------------------------
        // declared output container, when the kernel carries one
        let declared: Option<DType> = match &step.kernel {
            CompiledKernel::Threshold(tk) => Some(tk.out_dtype()),
            CompiledKernel::QConv(qc) => Some(qc.out_dtype()),
            CompiledKernel::QGemm(qg) => Some(qg.out_dtype()),
            CompiledKernel::QMatMul(qm) => Some(qm.out_dtype()),
            _ => None,
        };
        let passthrough = matches!(step.kernel, CompiledKernel::Reshape(_))
            || (matches!(step.kernel, CompiledKernel::Op(_)) && residency_passthrough(node));

        for &out in step.outputs.iter().flatten() {
            let out_dt = dt_of(out);
            let flag = int_resident.get_mut(out as usize);
            if let Some(want) = declared {
                if out_dt != want {
                    r.error(
                        Code::DtypeMismatch,
                        loc,
                        format!(
                            "kernel declares output container {want} but slot {out} is \
                             {out_dt} — the emitted buffer would land in the wrong \
                             dtype-keyed pool"
                        ),
                    );
                }
                if let Some(f) = flag {
                    *f = want != DType::F32;
                }
            } else if passthrough {
                let (in0_dt, in0_flag) = in0.unwrap_or((DType::F32, false));
                if out_dt != in0_dt && out_dt != DType::F32 {
                    r.error(
                        Code::DtypeMismatch,
                        loc,
                        format!(
                            "pass-through op '{}' re-emits its input container {in0_dt} \
                             but slot {out} is {out_dt}",
                            node.op_type
                        ),
                    );
                }
                if let Some(f) = flag {
                    *f = out_dt != DType::F32 && in0_flag;
                }
            } else {
                // packed float kernels and generic ops emit f32
                if out_dt != DType::F32 {
                    r.error(
                        Code::DtypeMismatch,
                        loc,
                        format!(
                            "'{}' emits f32 but its output slot {out} is declared {out_dt}",
                            node.op_type
                        ),
                    );
                }
                if let Some(f) = flag {
                    *f = false;
                }
            }
        }
    }
}

//! Fusion and schedule legality, re-derived from the source graph.
//!
//! Epilogue fusion deletes nodes from the schedule: a packed kernel
//! absorbs a chain of elementwise consumers, a quantized kernel absorbs
//! its `MultiThreshold`, and the fused step then produces the *last*
//! absorbed node's outputs. That is only observably correct when each
//! absorbed node was the **sole** consumer of its producer's single
//! output, reading it as the data (first) input, with the value not a
//! graph output — otherwise some other reader would see a value that no
//! longer exists.
//!
//! The compiler proves this during pass 1.5; this pass proves it
//! *again*, independently: the constant-folding + identity-elision walk
//! is replayed from the graph (constness is a closure property — no
//! tensor is evaluated), use counts are recounted, and every fused hop
//! recorded in a kernel's epilogue chain is re-matched against the
//! re-derived sole consumer. The walk also re-checks:
//!
//! * the step ↔ node correspondence itself (every schedulable node has
//!   exactly one step, in topological order),
//! * per-kernel step arity (a packed kernel bakes its constants in, so
//!   its step reads exactly the data input),
//! * batch-symbolic `Reshape` rewrites: the rewritten target must be
//!   the original with its baked leading 1 replaced by ONNX's `0`
//!   copy-dim, wildcards unique, and the declared-shape fallback order
//!   consistent, and
//! * the plan's input/output tables against the graph's.

use super::{Code, Location, VerifyReport};
use crate::ir::ModelGraph;
use crate::plan::kernel::{BatchReshape, Epilogue};
use crate::plan::{CompiledKernel, ExecutionPlan};
use std::collections::{BTreeMap, BTreeSet};

/// Resolve an identity-elided name to its canonical runtime name
/// (mirrors `plan/compile.rs::canon`).
fn canon<'g>(alias: &BTreeMap<&'g str, &'g str>, name: &'g str) -> &'g str {
    alias.get(name).copied().unwrap_or(name)
}

/// The node op a fused float epilogue stage must have come from.
fn ep_op(e: &Epilogue) -> &'static str {
    match e {
        Epilogue::Relu => "Relu",
        Epilogue::Quant { .. } => "Quant",
        Epilogue::Bipolar { .. } => "BipolarQuant",
        Epilogue::BatchNorm { .. } => "BatchNormalization",
    }
}

pub(super) fn check(plan: &ExecutionPlan<'_>, graph: &ModelGraph, r: &mut VerifyReport) {
    let nn = graph.nodes.len();
    for (si, step) in plan.steps.iter().enumerate() {
        if step.node_idx >= nn || step.out_node_idx >= nn {
            r.error(
                Code::BadNodeIndex,
                Location::Step(si),
                format!(
                    "step references node {} / out-node {} of {nn}",
                    step.node_idx, step.out_node_idx
                ),
            );
            return;
        }
    }

    // plan output table == graph output table, in declaration order
    if plan.outputs.len() != graph.outputs.len() {
        r.error(
            Code::OutputMissing,
            Location::Plan,
            format!(
                "plan extracts {} outputs, graph declares {}",
                plan.outputs.len(),
                graph.outputs.len()
            ),
        );
    } else {
        for (i, (po, vi)) in plan.outputs.iter().zip(&graph.outputs).enumerate() {
            if po.name != vi.name {
                r.error(
                    Code::OutputMissing,
                    Location::Output(i),
                    format!("plan extracts '{}' where the graph declares '{}'", po.name, vi.name),
                );
            }
        }
    }
    // plan input table == the graph's non-initializer-shadowed inputs
    let want_inputs: Vec<&str> = graph
        .inputs
        .iter()
        .filter(|vi| !graph.initializers.contains_key(&vi.name))
        .map(|vi| vi.name.as_str())
        .collect();
    if plan.inputs.len() != want_inputs.len()
        || plan.inputs.iter().zip(&want_inputs).any(|(pi, &w)| pi.name != w)
    {
        r.error(
            Code::GraphMismatch,
            Location::Plan,
            format!(
                "plan input table {:?} does not match the graph's runtime inputs {want_inputs:?}",
                plan.inputs.iter().map(|pi| pi.name.as_str()).collect::<Vec<_>>()
            ),
        );
    }

    let Ok(order) = graph.topo_order() else {
        r.error(
            Code::GraphMismatch,
            Location::Plan,
            "source graph has no topological order".to_string(),
        );
        return;
    };

    // ------------------------------------------------------------------
    // Replay pass 1. Which nodes fold is a *closure* property (all
    // present inputs constant, through identity aliases), so the walk
    // needs no tensor evaluation — if the plan compiled, every fold
    // succeeded.
    // ------------------------------------------------------------------
    let mut const_names: BTreeSet<&str> =
        graph.initializers.keys().map(String::as_str).collect();
    let mut alias: BTreeMap<&str, &str> = BTreeMap::new();
    let mut kept: Vec<usize> = Vec::new();
    for &i in &order {
        let node = &graph.nodes[i];
        if node.present_inputs().all(|n| const_names.contains(canon(&alias, n))) {
            for out in &node.outputs {
                const_names.insert(out.as_str());
            }
            continue;
        }
        if node.op_type == "Identity" && node.outputs.len() == 1 {
            let mut present = node.present_inputs();
            if let (Some(src), None) = (present.next(), present.next()) {
                let c = canon(&alias, src);
                alias.insert(node.outputs[0].as_str(), c);
                continue;
            }
        }
        kept.push(i);
    }

    // use counts / consumer lists over canonical names, kept nodes only
    let mut uses: BTreeMap<&str, usize> = BTreeMap::new();
    let mut users: BTreeMap<&str, Vec<usize>> = BTreeMap::new();
    for (ki, &ni) in kept.iter().enumerate() {
        for raw in graph.nodes[ni].present_inputs() {
            let nm = canon(&alias, raw);
            *uses.entry(nm).or_insert(0) += 1;
            users.entry(nm).or_default().push(ki);
        }
    }
    let out_set: BTreeSet<&str> =
        graph.outputs.iter().map(|vi| canon(&alias, vi.name.as_str())).collect();

    // The sole-consumer proof, re-derived (mirrors
    // `plan/compile.rs::FuseCtx::sole_consumer`): single output, value
    // internal, used exactly once, by one later unconsumed node that
    // reads it as its data (first) input.
    let sole_consumer = |start_ki: usize, node_idx: usize, consumed: &[bool]| -> Option<usize> {
        let tail = &graph.nodes[node_idx];
        if tail.outputs.len() != 1 {
            return None;
        }
        let out_nm = canon(&alias, tail.outputs[0].as_str());
        if out_set.contains(out_nm) || uses.get(out_nm).copied().unwrap_or(0) != 1 {
            return None;
        }
        let uk = match users.get(out_nm) {
            Some(v) if v.len() == 1 => v[0],
            _ => return None,
        };
        if consumed[uk] || uk <= start_ki {
            return None;
        }
        let unode = &graph.nodes[kept[uk]];
        if unode.inputs.first().map(|s| canon(&alias, s.as_str())) != Some(out_nm) {
            return None;
        }
        Some(uk)
    };

    let mut consumed = vec![false; kept.len()];
    let mut ki = 0usize;
    for (si, step) in plan.steps.iter().enumerate() {
        let loc = Location::Step(si);
        while ki < kept.len() && consumed[ki] {
            ki += 1;
        }
        let Some(&base_node) = kept.get(ki) else {
            r.error(
                Code::GraphMismatch,
                loc,
                "more plan steps than schedulable graph nodes".to_string(),
            );
            return;
        };
        if base_node != step.node_idx {
            r.error(
                Code::GraphMismatch,
                loc,
                format!(
                    "step compiled from node {} ('{}') but the re-derived schedule expects \
                     node {base_node} ('{}')",
                    step.node_idx, graph.nodes[step.node_idx].name, graph.nodes[base_node].name
                ),
            );
            return;
        }
        let base_ki = ki;
        ki += 1;

        // per-kernel step arity: packed/quantized kernels bake their
        // constants in and read exactly the data input (Gemm keeps a
        // runtime C when B-only packing applied)
        let node = &graph.nodes[step.node_idx];
        let expect_arity = match &step.kernel {
            CompiledKernel::Op(_) => node.present_inputs().count(),
            CompiledKernel::Gemm(pg) => 1 + usize::from(pg.runtime_bias()),
            _ => 1,
        };
        if step.inputs.len() != expect_arity {
            r.error(
                Code::StepArity,
                loc,
                format!(
                    "step has {} runtime inputs but its kernel expects {expect_arity}",
                    step.inputs.len()
                ),
            );
        }

        if let CompiledKernel::Reshape(br) = &step.kernel {
            check_batch_reshape(r, loc, node.op_type.as_str(), br);
        }

        // re-prove each fused hop against the re-derived graph facts
        let hops: Vec<&'static str> = match &step.kernel {
            CompiledKernel::Conv(pc) => pc.epilogue().iter().map(ep_op).collect(),
            CompiledKernel::Gemm(pg) => pg.epilogue().iter().map(ep_op).collect(),
            CompiledKernel::MatMul(pm) => pm.epilogue().iter().map(ep_op).collect(),
            CompiledKernel::QConv(qc) if qc.has_fused_threshold() => vec!["MultiThreshold"],
            CompiledKernel::QGemm(qg) if qg.has_fused_threshold() => vec!["MultiThreshold"],
            CompiledKernel::QMatMul(qm) if qm.has_fused_threshold() => vec!["MultiThreshold"],
            _ => Vec::new(),
        };
        let mut cur = step.node_idx;
        let mut broke = false;
        for want in &hops {
            let Some(uk) = sole_consumer(base_ki, cur, &consumed) else {
                r.error(
                    Code::FusionNotSoleConsumer,
                    loc,
                    format!(
                        "fused '{want}' stage: node '{}' has no sole later consumer reading \
                         it as the data input — absorbing one changes observable behavior",
                        graph.nodes[cur].name
                    ),
                );
                broke = true;
                break;
            };
            let unode = &graph.nodes[kept[uk]];
            if unode.op_type != *want {
                r.error(
                    Code::FusionChainBroken,
                    loc,
                    format!(
                        "fused stage expects a '{want}' consumer but the sole consumer is \
                         '{}' ('{}')",
                        unode.op_type, unode.name
                    ),
                );
                broke = true;
                break;
            }
            consumed[uk] = true;
            cur = kept[uk];
        }
        if !broke && cur != step.out_node_idx {
            r.error(
                Code::FusionLengthMismatch,
                loc,
                format!(
                    "step declares the outputs of node {} but its re-derived epilogue \
                     chain ends at node {cur}",
                    step.out_node_idx
                ),
            );
        }
    }
    while ki < kept.len() && consumed[ki] {
        ki += 1;
    }
    if let Some(&ni) = kept.get(ki) {
        r.error(
            Code::GraphMismatch,
            Location::Plan,
            format!(
                "graph node '{}' ({}) requires a runtime step but the schedule has none",
                graph.nodes[ni].name, graph.nodes[ni].op_type
            ),
        );
    }
}

/// Batch-symbolic rewrite well-formedness: the rewritten (batched)
/// target must be the original with its baked leading 1 replaced by
/// ONNX's `0` copy-dim — anything else changes declared-shape results.
fn check_batch_reshape(r: &mut VerifyReport, loc: Location, op: &str, br: &BatchReshape) {
    if op != "Reshape" {
        r.error(
            Code::BatchReshapeMalformed,
            loc,
            format!("batch-symbolic kernel compiled from a '{op}' node"),
        );
    }
    let orig = br.orig();
    let batched = br.batched();
    if orig.first() != Some(&1) || orig.len() < 2 {
        r.error(
            Code::BatchReshapeMalformed,
            loc,
            format!(
                "rewritten target {orig:?} does not bake a leading batch of 1 over at \
                 least one trailing dim — the rewrite premise fails"
            ),
        );
        return;
    }
    if orig[1..].contains(&0) {
        r.error(
            Code::BatchReshapeMalformed,
            loc,
            format!(
                "rewritten target {orig:?} mixes the baked batch with positional \
                 copy-dims — the compiler must decline these"
            ),
        );
    }
    if orig[1..].iter().filter(|&&d| d == -1).count() > 1 {
        r.error(
            Code::BatchReshapeMalformed,
            loc,
            format!("rewritten target {orig:?} has more than one wildcard"),
        );
    }
    if batched.len() != orig.len()
        || batched.first() != Some(&0)
        || batched[1..] != orig[1..]
    {
        r.error(
            Code::BatchReshapeMalformed,
            loc,
            format!(
                "batched form {batched:?} is not the original target {orig:?} with its \
                 leading 1 rewritten to the 0 copy-dim"
            ),
        );
    }
    if br.try_orig_first() == orig[1..].contains(&-1) {
        r.error(
            Code::BatchReshapeMalformed,
            loc,
            format!(
                "declared-shape fallback order (try_orig_first = {}) disagrees with \
                 wildcard presence in {orig:?}",
                br.try_orig_first()
            ),
        );
    }
}

//! Static plan verification: invariant checking over compiled
//! [`ExecutionPlan`]s, without executing them.
//!
//! The plan compiler ([`crate::plan`]) makes a stack of claims when it
//! lowers a [`ModelGraph`]: every slot read happens inside the value's
//! live range with a single writer per range, the dtype-keyed slot table
//! matches what each kernel actually emits, quantized kernels' `i32`
//! accumulators stay inside the f32-exact `±2^24` window so integer
//! execution is byte-identical to float, and every fused epilogue chain
//! really was the sole consumer of its producer. The executor *trusts*
//! these claims — the hot loop indexes slots without checking.
//!
//! This module re-derives each claim from first principles and reports
//! every violation as a typed [`Diagnostic`]:
//!
//! * **slot lifetimes** ([`Code::ReadBeforeWrite`] & co.) — an abstract
//!   interpretation of the schedule over a slot-liveness bitmap: reads
//!   only of live slots, releases only of slots the step actually reads,
//!   no write over a live value, the end-of-schedule live set is exactly
//!   the graph outputs.
//! * **dtype flow** ([`Code::DtypeMismatch`] & co.) — each kernel's
//!   declared output container must match the slot table, integer-
//!   resident edges must be produced by an integer-emitting kernel chain
//!   (threshold/quantized kernels, propagated through the dtype-
//!   polymorphic pass-through ops), and kernels with no integer path
//!   must never read an integer-resident slot.
//! * **arithmetic safety** ([`Code::AccumulatorUnbounded`] & co.) — the
//!   `|x| · |w| · k + |c| < 2^24` accumulator bound is re-computed from
//!   each quantized kernel's claimed input range, the range itself is
//!   re-derived from the source graph via
//!   [`crate::transforms::infer_ranges`] and checked for containment,
//!   threshold rows are re-checked for per-channel monotonicity, and
//!   integer output containers must hold the proven level grid.
//! * **fusion / schedule legality** ([`Code::FusionNotSoleConsumer`] &
//!   co.) — the compiler's constant-folding + identity-elision walk is
//!   replayed (a closure property, no execution needed) and every fused
//!   epilogue hop is re-proved to be the sole later consumer reading the
//!   producer as its data input; batch-symbolic reshape rewrites and
//!   step arities are re-validated.
//!
//! # Deny-by-default in debug
//!
//! [`crate::plan::PlanOptions::verify`] runs this verifier at the tail
//! of every compile and fails compilation on any `Error`-severity
//! diagnostic. It defaults to **on in debug builds** (the whole unit
//! suite exercises the verifier against every plan it compiles) and off
//! in release, where verification is explicit: the `qonnx verify` CLI,
//! `plan --verify`, and the `verify_zoo` integration suite.
//!
//! # Self-test by mutation
//!
//! A verifier that only ever sees valid plans proves nothing about its
//! own checks. [`mutate`] provides single-fault plan mutators (swap
//! dependent steps, drop a release, forge a slot dtype, widen a claimed
//! range, unsort threshold rows, …); the unit tests assert that each
//! mutation class trips its expected diagnostic code and that unmutated
//! zoo plans verify clean.

use crate::ir::ModelGraph;
use crate::plan::ExecutionPlan;
use std::fmt;

mod arith;
mod dtype;
mod fusion;
pub mod mutate;
mod slots;

/// Diagnostic severity, ordered `Info < Warn < Error`.
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord)]
pub enum Severity {
    /// Narrative facts (the closing summary line).
    Info,
    /// Suspicious but not provably wrong — the plan still executes
    /// correctly or fails loudly at run time.
    Warn,
    /// A broken plan invariant: executing this plan may read stale
    /// buffers, confuse containers, or silently lose exactness.
    Error,
}

impl fmt::Display for Severity {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(match self {
            Severity::Info => "info",
            Severity::Warn => "warn",
            Severity::Error => "error",
        })
    }
}

/// Stable machine-readable diagnostic codes. The mutation self-tests
/// key on these, so mutators and checks can never drift apart silently.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Code {
    // slot lifetimes
    ReadBeforeWrite,
    SlotOutOfRange,
    DoubleRelease,
    ReleaseWithoutRead,
    OverwriteLive,
    OutputDead,
    SlotLeaked,
    DuplicateOutputSlot,
    // dtype flow
    DtypeMismatch,
    KernelInputDtype,
    IntegerEdgeUnjustified,
    // arithmetic safety
    AccumulatorUnbounded,
    InputRangeMismatch,
    UnprovenQuantInput,
    GridOverflowsContainer,
    ThresholdRowsUnsorted,
    ThresholdRowsMalformed,
    EpilogueChannelMismatch,
    // fusion / schedule legality
    FusionNotSoleConsumer,
    FusionChainBroken,
    FusionLengthMismatch,
    BadNodeIndex,
    BatchReshapeMalformed,
    OutputMissing,
    StepArity,
    GraphMismatch,
    // narrative
    Summary,
}

impl Code {
    /// Stable kebab-case name (rendered in reports, matched by tests).
    pub fn name(self) -> &'static str {
        match self {
            Code::ReadBeforeWrite => "read-before-write",
            Code::SlotOutOfRange => "slot-out-of-range",
            Code::DoubleRelease => "double-release",
            Code::ReleaseWithoutRead => "release-without-read",
            Code::OverwriteLive => "overwrite-live",
            Code::OutputDead => "output-dead",
            Code::SlotLeaked => "slot-leaked",
            Code::DuplicateOutputSlot => "duplicate-output-slot",
            Code::DtypeMismatch => "dtype-mismatch",
            Code::KernelInputDtype => "kernel-input-dtype",
            Code::IntegerEdgeUnjustified => "integer-edge-unjustified",
            Code::AccumulatorUnbounded => "accumulator-unbounded",
            Code::InputRangeMismatch => "input-range-mismatch",
            Code::UnprovenQuantInput => "unproven-quant-input",
            Code::GridOverflowsContainer => "grid-overflows-container",
            Code::ThresholdRowsUnsorted => "threshold-rows-unsorted",
            Code::ThresholdRowsMalformed => "threshold-rows-malformed",
            Code::EpilogueChannelMismatch => "epilogue-channel-mismatch",
            Code::FusionNotSoleConsumer => "fusion-not-sole-consumer",
            Code::FusionChainBroken => "fusion-chain-broken",
            Code::FusionLengthMismatch => "fusion-length-mismatch",
            Code::BadNodeIndex => "bad-node-index",
            Code::BatchReshapeMalformed => "batch-reshape-malformed",
            Code::OutputMissing => "output-missing",
            Code::StepArity => "step-arity",
            Code::GraphMismatch => "graph-mismatch",
            Code::Summary => "summary",
        }
    }
}

impl fmt::Display for Code {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(self.name())
    }
}

/// Where in the plan a diagnostic anchors.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Location {
    /// Plan-wide property (end-of-schedule live set, output table, …).
    Plan,
    /// Schedule step index.
    Step(usize),
    /// Preload index.
    Preload(usize),
    /// Plan input index.
    Input(usize),
    /// Plan output index.
    Output(usize),
}

impl fmt::Display for Location {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            Location::Plan => f.write_str("plan"),
            Location::Step(i) => write!(f, "step {i}"),
            Location::Preload(i) => write!(f, "preload {i}"),
            Location::Input(i) => write!(f, "input {i}"),
            Location::Output(i) => write!(f, "output {i}"),
        }
    }
}

/// One verifier finding.
#[derive(Debug, Clone)]
pub struct Diagnostic {
    pub severity: Severity,
    pub code: Code,
    pub location: Location,
    pub message: String,
}

impl fmt::Display for Diagnostic {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}[{}] @ {}: {}", self.severity, self.code, self.location, self.message)
    }
}

/// The verifier's result: every diagnostic, in check order.
#[derive(Debug, Clone, Default)]
pub struct VerifyReport {
    pub diagnostics: Vec<Diagnostic>,
}

impl VerifyReport {
    pub(crate) fn error(&mut self, code: Code, location: Location, message: String) {
        self.diagnostics.push(Diagnostic { severity: Severity::Error, code, location, message });
    }

    pub(crate) fn warn(&mut self, code: Code, location: Location, message: String) {
        self.diagnostics.push(Diagnostic { severity: Severity::Warn, code, location, message });
    }

    pub(crate) fn info(&mut self, code: Code, location: Location, message: String) {
        self.diagnostics.push(Diagnostic { severity: Severity::Info, code, location, message });
    }

    /// Any `Error`-severity diagnostic present.
    pub fn has_errors(&self) -> bool {
        self.diagnostics.iter().any(|d| d.severity == Severity::Error)
    }

    pub fn error_count(&self) -> usize {
        self.diagnostics.iter().filter(|d| d.severity == Severity::Error).count()
    }

    pub fn warn_count(&self) -> usize {
        self.diagnostics.iter().filter(|d| d.severity == Severity::Warn).count()
    }

    /// Whether any diagnostic carries `code` (the mutation tests' hook).
    pub fn has_code(&self, code: Code) -> bool {
        self.diagnostics.iter().any(|d| d.code == code)
    }

    /// No errors and no warnings (info lines allowed).
    pub fn is_clean(&self) -> bool {
        self.diagnostics.iter().all(|d| d.severity == Severity::Info)
    }

    /// Human-readable rendering, one diagnostic per line.
    pub fn render(&self) -> String {
        let mut s = String::new();
        for d in &self.diagnostics {
            s.push_str(&d.to_string());
            s.push('\n');
        }
        s
    }
}

/// Statically verify `plan` against the source `graph` it was compiled
/// from. Runs every check family and returns the full report; it never
/// fails — a broken plan is a report full of errors, not an `Err`.
///
/// The structural passes (dtype flow, arithmetic ranges, fusion
/// legality) re-derive facts from `graph`, so it must be the graph the
/// plan was compiled from; a mismatch is itself reported
/// ([`Code::GraphMismatch`]) and aborts the graph-dependent checks.
pub fn verify_plan(plan: &ExecutionPlan<'_>, graph: &ModelGraph) -> VerifyReport {
    let mut report = VerifyReport::default();
    slots::check(plan, &mut report);

    let graph_matches = plan.nodes.len() == graph.nodes.len()
        && plan
            .nodes
            .iter()
            .zip(&graph.nodes)
            .all(|(a, b)| a.op_type == b.op_type && a.inputs == b.inputs && a.outputs == b.outputs);
    if !graph_matches {
        report.error(
            Code::GraphMismatch,
            Location::Plan,
            format!(
                "plan node table ({} nodes) does not match the supplied source graph \
                 ({} nodes) — graph-dependent checks skipped",
                plan.nodes.len(),
                graph.nodes.len()
            ),
        );
        return report;
    }

    dtype::check(plan, &mut report);
    arith::check(plan, graph, &mut report);
    fusion::check(plan, graph, &mut report);

    let (e, w) = (report.error_count(), report.warn_count());
    report.info(
        Code::Summary,
        Location::Plan,
        format!(
            "verified plan '{}': {} steps, {} slots, {} preloads — {e} error(s), {w} warning(s)",
            plan.name(),
            plan.steps.len(),
            plan.slot_count,
            plan.preloads.len()
        ),
    );
    report
}

#[cfg(test)]
mod tests;

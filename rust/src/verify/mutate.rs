//! Single-fault plan mutators: the verifier's self-test harness.
//!
//! A verifier that only ever sees valid plans proves nothing about its
//! own checks — every check could be dead code and the suite would stay
//! green. Each function here injects exactly one class of fault into a
//! compiled [`ExecutionPlan`] (reorder dependent steps, drop a release,
//! forge a slot container, widen a claimed range, …) and the unit tests
//! assert that [`super::verify_plan`] trips the *expected* diagnostic
//! code for it.
//!
//! Every mutator returns `true` when it found a site to mutate and
//! `false` when the plan has no such site (so tests can skip rather than
//! silently pass). Mutators that reach inside a kernel use
//! [`Arc::get_mut`] and therefore must run on a **freshly compiled**
//! plan whose kernels are not shared (no engine has cloned them yet).

use crate::plan::{CompiledKernel, ExecutionPlan};
use crate::tensor::DType;
use std::sync::Arc;

/// Swap two adjacent steps where the second reads a slot the first
/// writes for the first time. After the swap, the reader runs before the
/// writer → `read-before-write`.
pub fn swap_adjacent_dependent_steps(plan: &mut ExecutionPlan<'_>) -> bool {
    // forward liveness sim: the swap only provably breaks the plan when
    // the shared slot is *dead* before the writer (a slot that was live
    // before could make the swapped read legal)
    let mut live = vec![false; plan.slot_count];
    for p in &plan.preloads {
        if let Some(f) = live.get_mut(p.slot as usize) {
            *f = true;
        }
    }
    for pi in &plan.inputs {
        if let Some(sl) = pi.slot {
            if let Some(f) = live.get_mut(sl as usize) {
                *f = true;
            }
        }
    }
    for i in 0..plan.steps.len().saturating_sub(1) {
        let (a, b) = (&plan.steps[i], &plan.steps[i + 1]);
        let dependent = a.outputs.iter().flatten().any(|&s| {
            b.inputs.contains(&s)
                && !a.release.contains(&s)
                && !live.get(s as usize).copied().unwrap_or(true)
        });
        if dependent {
            plan.steps.swap(i, i + 1);
            return true;
        }
        let step = &plan.steps[i];
        for &s in &step.release {
            if let Some(f) = live.get_mut(s as usize) {
                *f = false;
            }
        }
        for &s in step.outputs.iter().flatten() {
            if let Some(f) = live.get_mut(s as usize) {
                *f = true;
            }
        }
    }
    false
}

/// Remove a release whose slot a later step recycles. The later write
/// then lands on a still-live value → `overwrite-live`.
pub fn drop_release(plan: &mut ExecutionPlan<'_>) -> bool {
    for i in 0..plan.steps.len() {
        let candidate = plan.steps[i].release.iter().copied().find(|&s| {
            plan.steps[i + 1..]
                .iter()
                .any(|later| later.outputs.iter().flatten().any(|&o| o == s))
        });
        if let Some(s) = candidate {
            plan.steps[i].release.retain(|&x| x != s);
            return true;
        }
    }
    false
}

/// Forge the slot-container table under a kernel with a declared output
/// container (falling back to a preload slot) → `dtype-mismatch`.
pub fn lie_slot_dtype(plan: &mut ExecutionPlan<'_>) -> bool {
    let flip = |dt: DType| if dt == DType::F32 { DType::I32 } else { DType::F32 };
    for step in &plan.steps {
        let declared = matches!(
            step.kernel,
            CompiledKernel::Threshold(_)
                | CompiledKernel::QConv(_)
                | CompiledKernel::QGemm(_)
                | CompiledKernel::QMatMul(_)
        );
        if !declared {
            continue;
        }
        if let Some(&s) = step.outputs.iter().flatten().next() {
            if let Some(dt) = plan.slot_dtypes.get_mut(s as usize) {
                *dt = flip(*dt);
                return true;
            }
        }
    }
    if let Some(p) = plan.preloads.first() {
        let s = p.slot as usize;
        if let Some(dt) = plan.slot_dtypes.get_mut(s) {
            *dt = flip(*dt);
            return true;
        }
    }
    false
}

/// Widen a quantized kernel's claimed input range to ±2^30. The
/// re-computed accumulator bound then crosses 2^24 →
/// `accumulator-unbounded` (requires the kernel's weights to be
/// non-degenerate, i.e. `|w| · k ≥ 1`).
pub fn widen_quant_input_range(plan: &mut ExecutionPlan<'_>) -> bool {
    let wide = f64::from(1u32 << 30);
    set_first_quant_range(plan, -wide, wide)
}

/// Narrow a quantized kernel's claimed input range to `[0, 0]`. The
/// range provable from the source graph is no longer contained in the
/// claim → `input-range-mismatch`.
pub fn narrow_quant_input_range(plan: &mut ExecutionPlan<'_>) -> bool {
    set_first_quant_range(plan, 0.0, 0.0)
}

fn set_first_quant_range(plan: &mut ExecutionPlan<'_>, lo: f64, hi: f64) -> bool {
    for step in &mut plan.steps {
        match &mut step.kernel {
            CompiledKernel::QConv(qc) => {
                if let Some(qc) = Arc::get_mut(qc) {
                    qc.set_input_range(lo, hi);
                    return true;
                }
            }
            CompiledKernel::QGemm(qg) => {
                if let Some(qg) = Arc::get_mut(qg) {
                    qg.set_input_range(lo, hi);
                    return true;
                }
            }
            CompiledKernel::QMatMul(qm) => {
                if let Some(qm) = Arc::get_mut(qm) {
                    qm.set_input_range(lo, hi);
                    return true;
                }
            }
            _ => {}
        }
    }
    false
}

/// Swap a strictly-increasing adjacent pair inside one threshold row of
/// a standalone [`crate::plan::qkernel::ThresholdKernel`] →
/// `threshold-rows-unsorted`.
pub fn unsort_threshold_rows(plan: &mut ExecutionPlan<'_>) -> bool {
    for step in &mut plan.steps {
        let CompiledKernel::Threshold(tk) = &mut step.kernel else {
            continue;
        };
        let Some(tk) = Arc::get_mut(tk) else { continue };
        let (c, t) = (tk.channels(), tk.steps());
        let rows = tk.rows_mut();
        for ci in 0..c {
            for k in 0..t.saturating_sub(1) {
                let j = ci * t + k;
                if rows[j] < rows[j + 1] {
                    rows.swap(j, j + 1);
                    return true;
                }
            }
        }
    }
    false
}

/// Drop the final step of the schedule: the graph output it produced is
/// dead at the end → `output-dead` (and the re-derived schedule reports
/// the unscheduled node).
pub fn drop_step(plan: &mut ExecutionPlan<'_>) -> bool {
    plan.steps.pop().is_some()
}

/// Point the first graph output at a slot past the arena →
/// `slot-out-of-range`.
pub fn redirect_output_slot(plan: &mut ExecutionPlan<'_>) -> bool {
    let bad = plan.slot_count as u32 + 7;
    match plan.outputs.first_mut() {
        Some(po) => {
            po.slot = bad;
            true
        }
        None => false,
    }
}

//! Slot-lifetime and alias analysis: an abstract interpretation of the
//! schedule over a per-slot liveness bitmap.
//!
//! The executor's hot loop trusts the schedule completely — it indexes
//! `slots[sl]` without checking that anything was ever stored there, and
//! recycles released buffers into kernel scratch. This pass re-walks the
//! step timeline and proves the claims that make that safe:
//!
//! * every step input is live when read (no read-before-write, no
//!   read-after-release),
//! * releases are consistent: a step only releases slots it actually
//!   reads, and never releases a dead slot twice,
//! * writes respect the single-writer-per-live-range rule: a step output
//!   never lands on a slot whose previous value is still live
//!   (release-before-alloc makes same-step recycling legitimate),
//! * preloads and inputs agree with the dtype-keyed slot table (the
//!   dtype-confused-recycling check at the plan boundary), and
//! * the end-of-schedule live set is exactly the graph outputs: dead
//!   outputs are an error, extra live slots a leak warning.

use super::{Code, Location, VerifyReport};
use crate::plan::ExecutionPlan;
use crate::tensor::DType;

pub(super) fn check(plan: &ExecutionPlan<'_>, r: &mut VerifyReport) {
    let n = plan.slot_count;
    if plan.slot_dtypes.len() != n {
        r.error(
            Code::DtypeMismatch,
            Location::Plan,
            format!("slot dtype table has {} entries for {n} slots", plan.slot_dtypes.len()),
        );
    }

    let mut live = vec![false; n];
    let oob = |slot: u32| slot as usize >= n;

    for (i, p) in plan.preloads.iter().enumerate() {
        if oob(p.slot) {
            r.error(
                Code::SlotOutOfRange,
                Location::Preload(i),
                format!("preload '{}' bound to slot {} of {n}", p.name, p.slot),
            );
            continue;
        }
        let s = p.slot as usize;
        if live[s] {
            r.error(
                Code::OverwriteLive,
                Location::Preload(i),
                format!("preload '{}' rebinds slot {s}, which is already live", p.name),
            );
        }
        live[s] = true;
        let dt = p.value.as_tensor().dtype();
        if let Some(&table) = plan.slot_dtypes.get(s) {
            if table != dt {
                r.error(
                    Code::DtypeMismatch,
                    Location::Preload(i),
                    format!(
                        "preload '{}' holds {dt} but slot {s} is declared {table} — \
                         dtype-keyed recycling would hand the buffer to the wrong pool",
                        p.name
                    ),
                );
            }
        }
    }

    for (i, pi) in plan.inputs.iter().enumerate() {
        let Some(slot) = pi.slot else { continue };
        if oob(slot) {
            r.error(
                Code::SlotOutOfRange,
                Location::Input(i),
                format!("input '{}' bound to slot {slot} of {n}", pi.name),
            );
            continue;
        }
        let s = slot as usize;
        if live[s] {
            r.error(
                Code::OverwriteLive,
                Location::Input(i),
                format!("input '{}' rebinds slot {s}, which is already live", pi.name),
            );
        }
        live[s] = true;
        if let Some(&table) = plan.slot_dtypes.get(s) {
            if table != DType::F32 {
                r.error(
                    Code::DtypeMismatch,
                    Location::Input(i),
                    format!(
                        "input '{}' slot {s} is declared {table}, but callers bind f32 \
                         data at the graph edge",
                        pi.name
                    ),
                );
            }
        }
    }

    for (si, step) in plan.steps.iter().enumerate() {
        let loc = Location::Step(si);
        for &sl in &step.inputs {
            if oob(sl) {
                r.error(Code::SlotOutOfRange, loc, format!("reads slot {sl} of {n}"));
                continue;
            }
            if !live[sl as usize] {
                r.error(
                    Code::ReadBeforeWrite,
                    loc,
                    format!("reads slot {sl}, which is not live here (never written, or \
                             already released)"),
                );
            }
        }
        for &sl in &step.release {
            if oob(sl) {
                r.error(Code::SlotOutOfRange, loc, format!("releases slot {sl} of {n}"));
                continue;
            }
            if !live[sl as usize] {
                r.error(Code::DoubleRelease, loc, format!("releases slot {sl}, which is dead"));
            }
            if !step.inputs.contains(&sl) {
                r.error(
                    Code::ReleaseWithoutRead,
                    loc,
                    format!(
                        "releases slot {sl} without reading it — a release list must be the \
                         step's own last uses"
                    ),
                );
            }
            live[sl as usize] = false;
        }
        let mut written: Vec<u32> = Vec::new();
        for &sl in step.outputs.iter().flatten() {
            if oob(sl) {
                r.error(Code::SlotOutOfRange, loc, format!("writes slot {sl} of {n}"));
                continue;
            }
            if written.contains(&sl) {
                r.error(
                    Code::DuplicateOutputSlot,
                    loc,
                    format!("writes slot {sl} twice in one step"),
                );
                continue;
            }
            written.push(sl);
            if live[sl as usize] {
                r.error(
                    Code::OverwriteLive,
                    loc,
                    format!(
                        "writes slot {sl} while its previous value is still live \
                         (single-writer-per-live-range violation)"
                    ),
                );
            }
            live[sl as usize] = true;
        }
    }

    let mut is_output = vec![false; n];
    for (i, po) in plan.outputs.iter().enumerate() {
        if oob(po.slot) {
            r.error(
                Code::SlotOutOfRange,
                Location::Output(i),
                format!("output '{}' reads slot {} of {n}", po.name, po.slot),
            );
            continue;
        }
        let s = po.slot as usize;
        is_output[s] = true;
        if !live[s] {
            r.error(
                Code::OutputDead,
                Location::Output(i),
                format!(
                    "graph output '{}' slot {s} is not live at the end of the schedule",
                    po.name
                ),
            );
        }
    }
    for (s, &l) in live.iter().enumerate() {
        if l && !is_output[s] {
            r.warn(
                Code::SlotLeaked,
                Location::Plan,
                format!(
                    "slot {s} is still live at the end of the schedule but feeds no graph \
                     output (missing release — peak memory is higher than necessary)"
                ),
            );
        }
    }
}

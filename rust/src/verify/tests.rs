//! Verifier unit tests: clean plans verify clean, and every mutation
//! class from [`mutate`] trips its expected diagnostic code.

use super::{mutate, verify_plan, Code};
use crate::ir::{GraphBuilder, ModelGraph};
use crate::plan::{ExecutionPlan, PlanOptions};
use crate::tensor::Tensor;

/// `x -> MultiThreshold(const) -> MatMul(const w) -> y`: compiles to a
/// standalone `Threshold(i8)` step feeding a `QuantMatMul` — one step of
/// every kernel family the mutators target, in two steps.
fn tiny_quant_graph() -> ModelGraph {
    let mut b = GraphBuilder::new("verify-tiny");
    b.input("x", vec![1, 4]);
    b.initializer("t0", Tensor::new(vec![1, 3], vec![0.5, 1.5, 2.5]));
    b.node_in_domain(crate::ir::DOMAIN_FINN, "MultiThreshold", &["x", "t0"], &["xi"], &[]);
    b.initializer(
        "w",
        Tensor::new(vec![4, 2], vec![1.0, -2.0, 2.0, 1.0, -1.0, 1.0, 2.0, -1.0]),
    );
    b.node("MatMul", &["xi", "w"], &["y"], &[]);
    b.output("y", vec![1, 2]);
    b.finish().unwrap()
}

#[test]
fn tiny_quant_plan_verifies_clean() {
    let g = tiny_quant_graph();
    let plan = ExecutionPlan::compile(&g).unwrap();
    // premise of the mutation tests: the plan really exercises both the
    // threshold and quantized kernel families
    assert!(plan.summary().contains("Threshold"), "{}", plan.summary());
    let report = verify_plan(&plan, &g);
    assert!(report.is_clean(), "{}", report.render());
    assert!(report.has_code(Code::Summary));
}

#[test]
fn verify_is_deny_by_default_in_debug() {
    assert_eq!(PlanOptions::default().verify, cfg!(debug_assertions));
}

#[test]
fn mismatched_graph_is_reported_not_misverified() {
    let g = tiny_quant_graph();
    let plan = ExecutionPlan::compile(&g).unwrap();
    let mut b = GraphBuilder::new("other");
    b.input("x", vec![1, 4]);
    b.node("Relu", &["x"], &["y"], &[]);
    b.output("y", vec![1, 4]);
    let other = b.finish().unwrap();
    let report = verify_plan(&plan, &other);
    assert!(report.has_code(Code::GraphMismatch), "{}", report.render());
}

/// Compile the tiny graph, prove the baseline clean, apply exactly one
/// mutation, and assert the verifier reports the expected code as an
/// error.
fn check_mutation(mutator: fn(&mut ExecutionPlan<'_>) -> bool, expect: Code) {
    let g = tiny_quant_graph();
    let mut plan = ExecutionPlan::compile(&g).unwrap();
    assert!(verify_plan(&plan, &g).is_clean());
    assert!(mutator(&mut plan), "mutator found no site in the tiny plan");
    let report = verify_plan(&plan, &g);
    assert!(
        report.has_code(expect),
        "expected a {expect} diagnostic, got:\n{}",
        report.render()
    );
    assert!(report.has_errors(), "{}", report.render());
}

#[test]
fn mutation_swapped_dependent_steps_is_read_before_write() {
    check_mutation(mutate::swap_adjacent_dependent_steps, Code::ReadBeforeWrite);
}

#[test]
fn mutation_dropped_release_is_overwrite_live() {
    check_mutation(mutate::drop_release, Code::OverwriteLive);
}

#[test]
fn mutation_forged_slot_dtype_is_dtype_mismatch() {
    check_mutation(mutate::lie_slot_dtype, Code::DtypeMismatch);
}

#[test]
fn mutation_widened_range_is_accumulator_unbounded() {
    check_mutation(mutate::widen_quant_input_range, Code::AccumulatorUnbounded);
}

#[test]
fn mutation_narrowed_range_is_input_range_mismatch() {
    check_mutation(mutate::narrow_quant_input_range, Code::InputRangeMismatch);
}

#[test]
fn mutation_unsorted_thresholds_is_threshold_rows_unsorted() {
    check_mutation(mutate::unsort_threshold_rows, Code::ThresholdRowsUnsorted);
}

#[test]
fn mutation_dropped_step_is_output_dead() {
    check_mutation(mutate::drop_step, Code::OutputDead);
}

#[test]
fn mutation_redirected_output_is_slot_out_of_range() {
    check_mutation(mutate::redirect_output_slot, Code::SlotOutOfRange);
}

#[test]
fn tfc_plans_verify_clean_across_option_combos() {
    let mut g = crate::zoo::tfc(&crate::zoo::TfcParams::random(1, 1, 7)).unwrap();
    crate::transforms::cleanup(&mut g).unwrap();
    let combos = [
        PlanOptions::default(),
        PlanOptions { specialize: false, ..Default::default() },
        PlanOptions { fuse_epilogues: false, ..Default::default() },
        PlanOptions { quantize: false, ..Default::default() },
        PlanOptions { int_residency: false, ..Default::default() },
        PlanOptions { batch_symbolic: false, ..Default::default() },
    ];
    for (i, opts) in combos.iter().enumerate() {
        let plan = ExecutionPlan::compile_with(&g, opts).unwrap();
        let report = verify_plan(&plan, &g);
        assert!(!report.has_errors(), "combo {i}:\n{}", report.render());
    }
}

#[test]
fn streamlined_tfc_verifies_clean() {
    let mut g = crate::zoo::build("TFC-w1a1", 1, 32).unwrap();
    crate::transforms::cleanup(&mut g).unwrap();
    let sl = crate::streamline::try_streamline(&g).unwrap();
    assert!(sl.report.ok, "{}", sl.report.render());
    let plan = ExecutionPlan::compile(&sl.graph).unwrap();
    let report = verify_plan(&plan, &sl.graph);
    assert!(!report.has_errors(), "{}", report.render());
}

//! CNV: the VGG-like CIFAR-10 models of Table III (from the FINN paper),
//! with the raw-export variant whose conv→FC transition appears in Fig. 1.

use super::rng::Rng;
use crate::ir::{AttrValue, GraphBuilder, ModelGraph};
use crate::tensor::Tensor;
use anyhow::Result;

/// Conv channel plan: 3→64→64 →pool→ 128→128 →pool→ 256→256, then FC
/// 256→512→512→10 (Table III: 1,542,848 weights).
const CONV_PLAN: &[(usize, usize, bool)] = &[
    (3, 64, false),
    (64, 64, true),
    (64, 128, false),
    (128, 128, true),
    (128, 256, false),
    (256, 256, false),
];
const FC_PLAN: &[(usize, usize)] = &[(256, 512), (512, 512), (512, 10)];

/// Build CNV-wXaY. `raw_export = true` reproduces the uncleaned
/// Brevitas/PyTorch export: `Identity` nodes after weight constants and the
/// `Shape→Gather→Unsqueeze→Concat→Reshape` flatten chain of Fig. 1.
pub fn cnv(weight_bits: u32, act_bits: u32, seed: u64, raw_export: bool) -> Result<ModelGraph> {
    let name = format!("CNV-w{weight_bits}a{act_bits}");
    let mut b = GraphBuilder::new(&name);
    let mut rng = Rng::new(seed);
    b.input("x", vec![1, 3, 32, 32]);
    b.quant("x", "x_q", 1.0 / 255.0, 0.0, 8.0, false, false, "ROUND");
    let mut cur = "x_q".to_string();

    let quant_weight = |b: &mut GraphBuilder, tag: &str, w: Tensor, wbits: u32| -> String {
        let w_name = format!("{tag}_w");
        let wq_name = format!("{tag}_wq");
        b.initializer(&w_name, w);
        let src = if raw_export {
            // exporters leave an Identity between the constant and the quant
            let id_name = format!("{tag}_w_id");
            b.node("Identity", &[&w_name], &[&id_name], &[]);
            id_name
        } else {
            w_name
        };
        if wbits == 1 {
            b.bipolar_quant(&src, &wq_name, 0.25);
        } else {
            b.quant(&src, &wq_name, 0.25, 0.0, wbits as f32, true, true, "ROUND");
        }
        wq_name
    };

    for (i, &(cin, cout, pool)) in CONV_PLAN.iter().enumerate() {
        let tag = format!("conv{i}");
        let w = Tensor::new(vec![cout, cin, 3, 3], rng.he_weights(cout * cin * 9, cin * 9));
        let wq = quant_weight(&mut b, &tag, w, weight_bits);
        let conv_out = format!("{tag}_out");
        b.node(
            "Conv",
            &[&cur, &wq],
            &[&conv_out],
            &[("kernel_shape", AttrValue::Ints(vec![3, 3]))],
        );
        // batch norm (identity-initialized; training would set real params)
        let bn_out = format!("{tag}_bn");
        for (suffix, v) in [("scale", 1.0f32), ("bias", 0.0), ("mean", 0.0), ("var", 1.0)] {
            b.initializer(&format!("{tag}_bn_{suffix}"), Tensor::full(vec![cout], v));
        }
        b.node(
            "BatchNormalization",
            &[
                &conv_out,
                &format!("{tag}_bn_scale"),
                &format!("{tag}_bn_bias"),
                &format!("{tag}_bn_mean"),
                &format!("{tag}_bn_var"),
            ],
            &[&bn_out],
            &[],
        );
        let act_out = format!("{tag}_act");
        if act_bits == 1 {
            b.bipolar_quant(&bn_out, &act_out, 1.0);
        } else {
            b.quant(&bn_out, &act_out, 0.25, 0.0, act_bits as f32, true, false, "ROUND");
        }
        cur = act_out;
        if pool {
            let pool_out = format!("{tag}_pool");
            b.node(
                "MaxPool",
                &[&cur],
                &[&pool_out],
                &[("kernel_shape", AttrValue::Ints(vec![2, 2]))],
            );
            cur = pool_out;
        }
    }

    // conv→FC transition (the Fig. 1/2/3 region)
    if raw_export {
        b.initializer("flat_idx", Tensor::new_i64(vec![], vec![0]));
        b.initializer("flat_rest", Tensor::new_i64(vec![1], vec![-1]));
        b.node("Shape", &[&cur], &["flat_shape"], &[]);
        b.node("Gather", &["flat_shape", "flat_idx"], &["flat_b"], &[("axis", AttrValue::Int(0))]);
        b.node("Unsqueeze", &["flat_b"], &["flat_bu"], &[("axes", AttrValue::Ints(vec![0]))]);
        b.node("Concat", &["flat_bu", "flat_rest"], &["flat_target"], &[("axis", AttrValue::Int(0))]);
        b.node("Reshape", &[&cur, "flat_target"], &["flat"], &[]);
    } else {
        b.initializer("flat_target", Tensor::new_i64(vec![2], vec![1, 256]));
        b.node("Reshape", &[&cur, "flat_target"], &["flat"], &[]);
    }
    cur = "flat".to_string();

    for (i, &(fin, fout)) in FC_PLAN.iter().enumerate() {
        let tag = format!("fc{i}");
        let w = Tensor::new(vec![fin, fout], rng.he_weights(fin * fout, fin));
        let wq = quant_weight(&mut b, &tag, w, weight_bits);
        let out = format!("{tag}_out");
        b.node("MatMul", &[&cur, &wq], &[&out], &[]);
        cur = out;
        if i + 1 < FC_PLAN.len() {
            let act_out = format!("{tag}_act");
            if act_bits == 1 {
                b.bipolar_quant(&cur, &act_out, 1.0);
            } else {
                b.quant(&cur, &act_out, 0.25, 0.0, act_bits as f32, true, false, "ROUND");
            }
            cur = act_out;
        }
    }
    b.node("Identity", &[&cur], &["logits"], &[]);
    if raw_export {
        b.output_unknown("logits");
    } else {
        b.output("logits", vec![1, 10]);
    }
    let mut g = b.finish()?;
    g.doc = format!("CNV VGG-like CIFAR-10 model, {weight_bits}-bit weights / {act_bits}-bit activations");
    Ok(g)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::exec::execute_simple;
    use crate::metrics::analyze;
    use crate::transforms::cleanup;

    #[test]
    fn weights_match_table_iii() {
        let mut g = cnv(2, 2, 1, false).unwrap();
        cleanup(&mut g).unwrap();
        let r = analyze(&g).unwrap();
        // Table III: 1,542,848 weights; w2 -> 3,085,696 total weight bits
        assert_eq!(r.weights(), 1_542_848);
        assert_eq!(r.total_weight_bits(), 3_085_696);
        assert_eq!(r.layers.len(), 9);
    }

    #[test]
    fn macs_close_to_table_iii() {
        // Table III reports 57,906,176 (zoo counting script); our full count
        // including the 8-bit first conv is 59,461,376. Same ballpark, and
        // identical across bit-width variants as in the paper.
        let mut g = cnv(1, 1, 1, false).unwrap();
        cleanup(&mut g).unwrap();
        let r = analyze(&g).unwrap();
        assert_eq!(r.macs(), 59_461_376);
    }

    #[test]
    fn raw_export_has_fig1_structure() {
        let g = cnv(2, 2, 1, true).unwrap();
        let h = g.op_histogram();
        for op in ["Shape", "Gather", "Unsqueeze", "Concat", "Reshape", "Identity"] {
            assert!(h.contains_key(op), "raw export missing {op}");
        }
    }

    #[test]
    fn cleanup_collapses_fig1_to_fig2() {
        // Fig. 2: "the Shape, Gather, Unsqueeze, Concat, and Reshape
        // structure was collapsed into a single Reshape operation"
        let mut g = cnv(2, 2, 1, true).unwrap();
        let before = g.nodes.len();
        cleanup(&mut g).unwrap();
        let h = g.op_histogram();
        assert!(!h.contains_key("Shape"));
        assert!(!h.contains_key("Gather"));
        assert!(!h.contains_key("Unsqueeze"));
        assert!(!h.contains_key("Concat"));
        assert!(!h.contains_key("Identity"));
        assert_eq!(h["Reshape"], 1);
        assert!(g.nodes.len() < before);
        // intermediate tensors now have shapes (Fig. 2 caption)
        assert_eq!(g.tensor_shape("conv0_out"), Some(vec![1, 64, 30, 30]));
    }

    #[test]
    fn executes_and_matches_after_cleanup() {
        let g0 = cnv(2, 2, 3, true).unwrap();
        let mut g1 = g0.clone();
        cleanup(&mut g1).unwrap();
        let x = Tensor::new(vec![1, 3, 32, 32], (0..3072).map(|i| (i % 253) as f32 / 253.0).collect());
        let y0 = execute_simple(&g0, &x).unwrap();
        let y1 = execute_simple(&g1, &x).unwrap();
        assert_eq!(y0, y1);
        assert_eq!(y0.shape(), &[1, 10]);
    }
}

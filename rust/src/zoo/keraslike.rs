//! QKeras-style ingestion (paper §VI-A, Fig. 4).
//!
//! A minimal "keras-like" layer-config model description (the analog of a
//! stripped QKeras model) converted into a QONNX graph: quantizer
//! attributes on `QDense` layers become explicit `Quant` nodes on the
//! weight/bias tensors, and `QActivation` layers become a standard
//! activation followed by a `Quant` node — exactly the paper's conversion
//! recipe.

use super::rng::Rng;
use crate::ir::{GraphBuilder, ModelGraph};
use crate::tensor::Tensor;
use anyhow::{bail, Result};

/// `quantized_bits(bits, integer)`-style quantizer config.
#[derive(Debug, Clone, Copy)]
pub struct QuantizedBits {
    pub bits: u32,
    /// integer bits — sets the scale to `2^(integer - bits + 1)`
    pub integer: u32,
}

impl QuantizedBits {
    pub fn scale(&self) -> f32 {
        2f32.powi(self.integer as i32 - self.bits as i32 + 1)
    }
}

/// A keras-like layer.
#[derive(Debug, Clone)]
pub enum KerasLayer {
    /// QDense(units, kernel_quantizer, bias_quantizer)
    QDense {
        units: usize,
        kernel_quantizer: QuantizedBits,
        bias_quantizer: Option<QuantizedBits>,
    },
    /// QActivation("quantized_relu(bits)")
    QActivationRelu { bits: u32 },
    /// plain activations
    Relu,
    Softmax,
}

/// A keras-like sequential model description.
#[derive(Debug, Clone)]
pub struct KerasModel {
    pub name: String,
    pub input_dim: usize,
    pub layers: Vec<KerasLayer>,
}

impl KerasModel {
    /// The Fig. 4 example: one quantized Dense (weights+bias) followed by a
    /// quantized ReLU.
    pub fn fig4_example() -> KerasModel {
        KerasModel {
            name: "qkeras_fig4".into(),
            input_dim: 16,
            layers: vec![
                KerasLayer::QDense {
                    units: 64,
                    kernel_quantizer: QuantizedBits { bits: 6, integer: 0 },
                    bias_quantizer: Some(QuantizedBits { bits: 6, integer: 0 }),
                },
                KerasLayer::QActivationRelu { bits: 4 },
            ],
        }
    }
}

/// Convert a keras-like model into QONNX (the tf2onnx + Quant-node-handler
/// pipeline of §VI-A, steps 1–3, collapsed).
pub fn keras_to_qonnx(model: &KerasModel, seed: u64) -> Result<ModelGraph> {
    let mut b = GraphBuilder::new(&model.name);
    let mut rng = Rng::new(seed);
    b.input("x", vec![1, model.input_dim]);
    let mut cur = "x".to_string();
    let mut cur_dim = model.input_dim;
    for (i, layer) in model.layers.iter().enumerate() {
        match layer {
            KerasLayer::QDense { units, kernel_quantizer, bias_quantizer } => {
                let w_name = format!("dense{i}_kernel");
                let wq_name = format!("dense{i}_kernel_q");
                b.initializer(
                    &w_name,
                    Tensor::new(vec![cur_dim, *units], rng.he_weights(cur_dim * units, cur_dim)),
                );
                b.quant(
                    &w_name,
                    &wq_name,
                    kernel_quantizer.scale(),
                    0.0,
                    kernel_quantizer.bits as f32,
                    true,
                    false,
                    "ROUND",
                );
                let mm = format!("dense{i}_matmul");
                b.node("MatMul", &[&cur, &wq_name], &[&mm], &[]);
                cur = mm;
                if let Some(bq) = bias_quantizer {
                    let b_name = format!("dense{i}_bias");
                    let bq_name = format!("dense{i}_bias_q");
                    b.initializer(&b_name, Tensor::new(vec![*units], rng.he_weights(*units, cur_dim)));
                    b.quant(&b_name, &bq_name, bq.scale(), 0.0, bq.bits as f32, true, false, "ROUND");
                    let add = format!("dense{i}_biasadd");
                    b.node("Add", &[&cur, &bq_name], &[&add], &[]);
                    cur = add;
                }
                cur_dim = *units;
            }
            KerasLayer::QActivationRelu { bits } => {
                // "A QActivation layer is transformed into a standard
                // activation layer followed by a Quant node."
                let relu = format!("act{i}_relu");
                b.node("Relu", &[&cur], &[&relu], &[]);
                let q = format!("act{i}_q");
                b.quant(&relu, &q, 1.0 / 8.0, 0.0, *bits as f32, false, false, "ROUND");
                cur = q;
            }
            KerasLayer::Relu => {
                let relu = format!("act{i}_relu");
                b.node("Relu", &[&cur], &[&relu], &[]);
                cur = relu;
            }
            KerasLayer::Softmax => {
                let sm = format!("act{i}_softmax");
                b.node("Softmax", &[&cur], &[&sm], &[]);
                cur = sm;
            }
        }
    }
    if cur_dim == 0 {
        bail!("empty model");
    }
    b.node("Identity", &[&cur], &["y"], &[]);
    b.output("y", vec![1, cur_dim]);
    let mut g = b.finish()?;
    g.doc = format!("converted from keras-like config '{}' (QKeras-style ingestion)", model.name);
    Ok(g)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::exec::execute_simple;
    use crate::transforms::cleanup;

    #[test]
    fn quantized_bits_scale() {
        // quantized_bits(6, 0): scale 2^(0-6+1) = 1/32
        assert_eq!(QuantizedBits { bits: 6, integer: 0 }.scale(), 1.0 / 32.0);
        assert_eq!(QuantizedBits { bits: 8, integer: 7 }.scale(), 1.0);
    }

    #[test]
    fn fig4_structure() {
        // Fig. 4 right side: MatMul with Quant'd kernel, Add with Quant'd
        // bias, Relu followed by Quant
        let g = keras_to_qonnx(&KerasModel::fig4_example(), 1).unwrap();
        let h = g.op_histogram();
        assert_eq!(h["Quant"], 3); // kernel, bias, activation
        assert_eq!(h["MatMul"], 1);
        assert_eq!(h["Add"], 1);
        assert_eq!(h["Relu"], 1);
        // ordering: Relu immediately feeds the activation Quant
        let relu_out = &g.nodes.iter().find(|n| n.op_type == "Relu").unwrap().outputs[0];
        let cons = g.consumers(relu_out);
        assert_eq!(g.nodes[cons[0]].op_type, "Quant");
    }

    #[test]
    fn converted_model_executes() {
        let mut g = keras_to_qonnx(&KerasModel::fig4_example(), 2).unwrap();
        cleanup(&mut g).unwrap();
        let x = Tensor::new(vec![1, 16], (0..16).map(|v| v as f32 * 0.1 - 0.8).collect());
        let y = execute_simple(&g, &x).unwrap();
        assert_eq!(y.shape(), &[1, 64]);
        // quantized relu output: non-negative, on the 1/8 grid
        for &v in y.as_f32().unwrap() {
            assert!(v >= 0.0);
            assert!((v * 8.0).fract().abs() < 1e-5);
        }
    }
}

//! MobileNet-V1 w4a4 (Table III's ImageNet entry): depthwise-separable
//! convolutions with 4-bit weights/activations and 8-bit input.
//!
//! The depthwise convs are the reason QONNX needs channel-wise input
//! quantization support that `QLinearConv` lacks (paper §III).

use super::rng::Rng;
use crate::ir::{AttrValue, GraphBuilder, ModelGraph};
use crate::tensor::Tensor;
use anyhow::Result;

/// (stride, out_channels) for the 13 depthwise-separable blocks.
const BLOCKS: &[(usize, usize)] = &[
    (1, 64),
    (2, 128),
    (1, 128),
    (2, 256),
    (1, 256),
    (2, 512),
    (1, 512),
    (1, 512),
    (1, 512),
    (1, 512),
    (1, 512),
    (2, 1024),
    (1, 1024),
];

/// Build MobileNet-V1 wXaY at a given input resolution (224 = paper;
/// smaller for fast tests). 1000-class head.
pub fn mobilenet(weight_bits: u32, act_bits: u32, resolution: usize, seed: u64) -> Result<ModelGraph> {
    let name = format!("MobileNet-w{weight_bits}a{act_bits}");
    let mut b = GraphBuilder::new(&name);
    let mut rng = Rng::new(seed);
    b.input("x", vec![1, 3, resolution, resolution]);
    b.quant("x", "x_q", 1.0 / 255.0, 0.0, 8.0, false, false, "ROUND");
    let mut cur = "x_q".to_string();

    let conv = |b: &mut GraphBuilder,
                    tag: &str,
                    cur: &str,
                    cin: usize,
                    cout: usize,
                    k: usize,
                    stride: usize,
                    group: usize,
                    rng: &mut Rng|
     -> String {
        let w_name = format!("{tag}_w");
        let wq_name = format!("{tag}_wq");
        let w = Tensor::new(
            vec![cout, cin / group, k, k],
            rng.he_weights(cout * (cin / group) * k * k, (cin / group) * k * k),
        );
        b.initializer(&w_name, w);
        // channel-wise weight scales (the QONNX broadcast mechanism)
        let scales = Tensor::new(vec![cout, 1, 1, 1], (0..cout).map(|i| 0.25 + (i % 4) as f32 * 0.01).collect());
        let s_name = format!("{wq_name}_scale");
        let z_name = format!("{wq_name}_zeropt");
        let bw_name = format!("{wq_name}_bitwidth");
        b.initializer(&s_name, scales);
        b.scalar(&z_name, 0.0);
        b.scalar(&bw_name, weight_bits as f32);
        b.node_in_domain(
            crate::ir::DOMAIN_QONNX,
            "Quant",
            &[&w_name, &s_name, &z_name, &bw_name],
            &[&wq_name],
            &[
                ("signed", AttrValue::Int(1)),
                ("narrow", AttrValue::Int(1)),
                ("rounding_mode", AttrValue::Str("ROUND".into())),
            ],
        );
        let pad = (k / 2) as i64;
        let out = format!("{tag}_out");
        b.node(
            "Conv",
            &[cur, &wq_name],
            &[&out],
            &[
                ("kernel_shape", AttrValue::Ints(vec![k as i64, k as i64])),
                ("strides", AttrValue::Ints(vec![stride as i64, stride as i64])),
                ("pads", AttrValue::Ints(vec![pad, pad, pad, pad])),
                ("group", AttrValue::Int(group as i64)),
            ],
        );
        // BN + act quant
        let bn = format!("{tag}_bn");
        for (suffix, v) in [("scale", 1.0f32), ("bias", 0.0), ("mean", 0.0), ("var", 1.0)] {
            b.initializer(&format!("{tag}_bn_{suffix}"), Tensor::full(vec![cout], v));
        }
        b.node(
            "BatchNormalization",
            &[
                &out,
                &format!("{tag}_bn_scale"),
                &format!("{tag}_bn_bias"),
                &format!("{tag}_bn_mean"),
                &format!("{tag}_bn_var"),
            ],
            &[&bn],
            &[],
        );
        let act = format!("{tag}_act");
        b.node("Relu", &[&bn], &[&format!("{tag}_relu")], &[]);
        b.quant(&format!("{tag}_relu"), &act, 0.25, 0.0, act_bits as f32, false, false, "ROUND");
        act
    };

    // stem: 3x3/2, 32 channels
    cur = conv(&mut b, "stem", &cur, 3, 32, 3, 2, 1, &mut rng);
    let mut channels = 32usize;
    for (i, &(stride, cout)) in BLOCKS.iter().enumerate() {
        // depthwise 3x3
        cur = conv(&mut b, &format!("dw{i}"), &cur, channels, channels, 3, stride, channels, &mut rng);
        // pointwise 1x1
        cur = conv(&mut b, &format!("pw{i}"), &cur, channels, cout, 1, 1, 1, &mut rng);
        channels = cout;
    }
    b.node("GlobalAveragePool", &[&cur], &["gap"], &[]);
    b.initializer("head_target", Tensor::new_i64(vec![2], vec![1, 1024]));
    b.node("Reshape", &["gap", "head_target"], &["gap_flat"], &[]);
    let w = Tensor::new(vec![1024, 1000], rng.he_weights(1024 * 1000, 1024));
    b.initializer("head_w", w);
    b.quant("head_w", "head_wq", 0.25, 0.0, weight_bits as f32, true, true, "ROUND");
    b.node("MatMul", &["gap_flat", "head_wq"], &["logits"], &[]);
    b.output("logits", vec![1, 1000]);
    let mut g = b.finish()?;
    g.doc = format!(
        "MobileNet-V1 {weight_bits}-bit/{act_bits}-bit with channel-wise weight scales, input {resolution}x{resolution}"
    );
    Ok(g)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::metrics::analyze;
    use crate::transforms::cleanup;

    #[test]
    fn weights_match_table_iii() {
        // Table III reports 4,208,224 weights; the standard MobileNet-V1
        // parameter count (conv + FC, no BN/bias) is 4,209,088 — an 864
        // (0.02%, one stem kernel) bookkeeping delta vs. the zoo script.
        let mut g = mobilenet(4, 4, 32, 1).unwrap();
        cleanup(&mut g).unwrap();
        let r = analyze(&g).unwrap();
        assert_eq!(r.weights(), 4_209_088);
        assert!((r.weights() as i64 - 4_208_224i64).abs() < 1000);
        assert_eq!(r.total_weight_bits(), 4 * 4_209_088);
        // 1 stem + 13 dw + 13 pw + 1 head = 28 compute layers
        assert_eq!(r.layers.len(), 28);
    }

    #[test]
    fn executes_at_low_resolution() {
        let mut g = mobilenet(4, 4, 32, 2).unwrap();
        cleanup(&mut g).unwrap();
        assert_eq!(g.tensor_shape("logits"), Some(vec![1, 1000]));
        // depthwise conv uses grouped channels
        let dw = g.nodes.iter().find(|n| n.op_type == "Conv" && n.attr_int_or("group", 1) == 32).unwrap();
        assert_eq!(dw.attr_int_or("group", 1), 32);
    }

    #[test]
    fn channelwise_weight_scales_present() {
        let g = mobilenet(4, 4, 32, 1).unwrap();
        let s = &g.initializers["stem_wq_scale"];
        assert_eq!(s.shape(), &[32, 1, 1, 1]);
    }
}

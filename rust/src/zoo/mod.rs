//! The QONNX model zoo (paper §VI-E, Table III) plus synthetic datasets
//! and deterministic model construction.

mod cnv;
mod keraslike;
mod mobilenet;
pub mod rng;
pub mod synth_data;
mod tfc;

pub use cnv::cnv;
pub use keraslike::{keras_to_qonnx, KerasLayer, KerasModel, QuantizedBits};
pub use mobilenet::mobilenet;
pub use synth_data::{synth_cifar, synth_digits, synth_digits_noisy, Dataset};
pub use tfc::{tfc, tfc_batch, DenseParams, TfcParams};

use crate::ir::ModelGraph;
use anyhow::Result;

/// All seven Table III zoo entries, by name.
pub const ZOO_NAMES: &[&str] = &[
    "MobileNet-w4a4",
    "CNV-w1a1",
    "CNV-w1a2",
    "CNV-w2a2",
    "TFC-w1a1",
    "TFC-w1a2",
    "TFC-w2a2",
];

/// Paper-reported accuracy per zoo model (Table III), for EXPERIMENTS.md
/// side-by-side reporting.
pub fn paper_accuracy(name: &str) -> Option<f64> {
    Some(match name {
        "MobileNet-w4a4" => 71.14,
        "CNV-w1a1" => 84.22,
        "CNV-w1a2" => 87.80,
        "CNV-w2a2" => 89.03,
        "TFC-w1a1" => 93.17,
        "TFC-w1a2" => 94.79,
        "TFC-w2a2" => 96.60,
        _ => return None,
    })
}

/// Dataset tier of a zoo model (Fig. 5's three bands).
pub fn dataset_of(name: &str) -> &'static str {
    if name.starts_with("MobileNet") {
        "ImageNet"
    } else if name.starts_with("CNV") {
        "CIFAR-10"
    } else {
        "MNIST"
    }
}

/// Build a zoo model by Table III name. `mobilenet_resolution` lets
/// benches trade fidelity for speed (224 = paper).
pub fn build(name: &str, seed: u64, mobilenet_resolution: usize) -> Result<ModelGraph> {
    let parse = |s: &str| -> (u32, u32) {
        let wa = s.rsplit('-').next().unwrap(); // "w1a2"
        let a_pos = wa.find('a').unwrap();
        (wa[1..a_pos].parse().unwrap(), wa[a_pos + 1..].parse().unwrap())
    };
    match name {
        n if n.starts_with("TFC") => {
            let (w, a) = parse(n);
            tfc(&TfcParams::random(w, a, seed))
        }
        n if n.starts_with("CNV") => {
            let (w, a) = parse(n);
            cnv(w, a, seed, false)
        }
        n if n.starts_with("MobileNet") => {
            let (w, a) = parse(n);
            mobilenet(w, a, mobilenet_resolution, seed)
        }
        other => anyhow::bail!("unknown zoo model '{other}'"),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn builds_every_zoo_entry() {
        for name in ZOO_NAMES {
            let g = build(name, 1, 32).unwrap();
            g.validate().unwrap();
            assert!(paper_accuracy(name).is_some());
        }
    }

    #[test]
    fn dataset_tiers() {
        assert_eq!(dataset_of("TFC-w1a1"), "MNIST");
        assert_eq!(dataset_of("CNV-w2a2"), "CIFAR-10");
        assert_eq!(dataset_of("MobileNet-w4a4"), "ImageNet");
    }
}

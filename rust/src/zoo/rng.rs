//! Deterministic PRNG (xorshift64*) — no rand crate in the vendor set, and
//! determinism across runs is a feature for reproducible experiments.

/// xorshift64* generator.
#[derive(Debug, Clone)]
pub struct Rng {
    state: u64,
    /// cached second Box-Muller sample
    spare: Option<f32>,
}

impl Rng {
    pub fn new(seed: u64) -> Rng {
        Rng { state: seed.max(1), spare: None }
    }

    pub fn next_u64(&mut self) -> u64 {
        let mut x = self.state;
        x ^= x >> 12;
        x ^= x << 25;
        x ^= x >> 27;
        self.state = x;
        x.wrapping_mul(0x2545F4914F6CDD1D)
    }

    /// Uniform in [0, 1).
    pub fn uniform(&mut self) -> f32 {
        (self.next_u64() >> 40) as f32 / (1u64 << 24) as f32
    }

    /// Uniform in [lo, hi).
    pub fn range(&mut self, lo: f32, hi: f32) -> f32 {
        lo + self.uniform() * (hi - lo)
    }

    /// Uniform integer in [0, n).
    pub fn below(&mut self, n: usize) -> usize {
        (self.next_u64() % n as u64) as usize
    }

    /// Standard normal via Box-Muller.
    pub fn gaussian(&mut self) -> f32 {
        if let Some(s) = self.spare.take() {
            return s;
        }
        let u1 = self.uniform().max(1e-9);
        let u2 = self.uniform();
        let r = (-2.0 * u1.ln()).sqrt();
        let theta = 2.0 * std::f32::consts::PI * u2;
        self.spare = Some(r * theta.sin());
        r * theta.cos()
    }

    /// He-style initialization for a fan-in of `fan_in`.
    pub fn he_weights(&mut self, count: usize, fan_in: usize) -> Vec<f32> {
        let std = (2.0 / fan_in as f32).sqrt();
        (0..count).map(|_| self.gaussian() * std).collect()
    }

    /// Fisher-Yates shuffle of indices 0..n.
    pub fn permutation(&mut self, n: usize) -> Vec<usize> {
        let mut idx: Vec<usize> = (0..n).collect();
        for i in (1..n).rev() {
            idx.swap(i, self.below(i + 1));
        }
        idx
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn uniform_in_range() {
        let mut r = Rng::new(7);
        for _ in 0..1000 {
            let v = r.uniform();
            assert!((0.0..1.0).contains(&v));
        }
    }

    #[test]
    fn gaussian_moments() {
        let mut r = Rng::new(12345);
        let n = 20000;
        let samples: Vec<f32> = (0..n).map(|_| r.gaussian()).collect();
        let mean: f32 = samples.iter().sum::<f32>() / n as f32;
        let var: f32 = samples.iter().map(|v| (v - mean).powi(2)).sum::<f32>() / n as f32;
        assert!(mean.abs() < 0.05, "mean {mean}");
        assert!((var - 1.0).abs() < 0.1, "var {var}");
    }

    #[test]
    fn permutation_is_permutation() {
        let mut r = Rng::new(3);
        let p = r.permutation(50);
        let mut sorted = p.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..50).collect::<Vec<_>>());
    }
}

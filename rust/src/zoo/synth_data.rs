//! Synthetic datasets (DESIGN.md §3 substitution for MNIST / CIFAR-10).
//!
//! * `synth_digits` — 28×28 grayscale "MNIST-like": one of 10 glyph
//!   bitmaps rendered at a random offset/scale with additive noise.
//! * `synth_cifar` — 3×32×32 "CIFAR-like": class = (dominant color hue ×
//!   stripe orientation) combinations, with noise. Harder than digits.
//!
//! Both generate deterministic labelled datasets from a seed; what the
//! Fig. 5 / Table III reproduction needs is *one fixed task* on which
//! accuracy responds to weight/activation precision the way the paper's
//! does.

use super::rng::Rng;

/// 7×5 digit glyph font (rows of 5 bits, 0..9).
const GLYPHS: [[u8; 7]; 10] = [
    [0b01110, 0b10001, 0b10011, 0b10101, 0b11001, 0b10001, 0b01110], // 0
    [0b00100, 0b01100, 0b00100, 0b00100, 0b00100, 0b00100, 0b01110], // 1
    [0b01110, 0b10001, 0b00001, 0b00010, 0b00100, 0b01000, 0b11111], // 2
    [0b11111, 0b00010, 0b00100, 0b00010, 0b00001, 0b10001, 0b01110], // 3
    [0b00010, 0b00110, 0b01010, 0b10010, 0b11111, 0b00010, 0b00010], // 4
    [0b11111, 0b10000, 0b11110, 0b00001, 0b00001, 0b10001, 0b01110], // 5
    [0b00110, 0b01000, 0b10000, 0b11110, 0b10001, 0b10001, 0b01110], // 6
    [0b11111, 0b00001, 0b00010, 0b00100, 0b01000, 0b01000, 0b01000], // 7
    [0b01110, 0b10001, 0b10001, 0b01110, 0b10001, 0b10001, 0b01110], // 8
    [0b01110, 0b10001, 0b10001, 0b01111, 0b00001, 0b00010, 0b01100], // 9
];

/// A labelled dataset of flattened images in [0, 1].
#[derive(Debug, Clone)]
pub struct Dataset {
    /// `[n, dim]` row-major
    pub images: Vec<f32>,
    pub labels: Vec<usize>,
    pub dim: usize,
    pub classes: usize,
}

impl Dataset {
    pub fn len(&self) -> usize {
        self.labels.len()
    }

    pub fn is_empty(&self) -> bool {
        self.labels.is_empty()
    }

    pub fn image(&self, i: usize) -> &[f32] {
        &self.images[i * self.dim..(i + 1) * self.dim]
    }
}

/// Render one 28×28 digit: glyph scaled 3×, random offset, noise.
fn render_digit(rng: &mut Rng, class: usize, out: &mut [f32]) {
    debug_assert_eq!(out.len(), 28 * 28);
    out.fill(0.0);
    let glyph = &GLYPHS[class];
    let scale = 3; // 15 wide, 21 tall
    let gw = 5 * scale;
    let _gh = 7 * scale; // glyph height (offset range uses fixed bounds)
    // modest jitter keeps classes learnable by a small MLP
    let ox = 4 + rng.below(6).min(28 - gw - 4);
    let oy = 1 + rng.below(5);
    let intensity = rng.range(0.75, 1.0);
    for (gy, row) in glyph.iter().enumerate() {
        for gx in 0..5 {
            if row & (1 << (4 - gx)) != 0 {
                for sy in 0..scale {
                    for sx in 0..scale {
                        let y = oy + gy * scale + sy;
                        let x = ox + gx * scale + sx;
                        out[y * 28 + x] = intensity;
                    }
                }
            }
        }
    }
    // noise + clamp
    for v in out.iter_mut() {
        *v = (*v + rng.gaussian() * 0.12).clamp(0.0, 1.0);
    }
}

/// Generate `n` MNIST-like samples (dim 784, 10 classes).
pub fn synth_digits(n: usize, seed: u64) -> Dataset {
    synth_digits_noisy(n, seed, 0.0)
}

/// `synth_digits` with extra additive noise of std `sigma` — used by the
/// Fig. 5 bench to de-saturate accuracy so precision differences show.
pub fn synth_digits_noisy(n: usize, seed: u64, sigma: f32) -> Dataset {
    let mut rng = Rng::new(seed);
    let mut images = vec![0f32; n * 784];
    let mut labels = Vec::with_capacity(n);
    for i in 0..n {
        let class = i % 10;
        let img = &mut images[i * 784..(i + 1) * 784];
        render_digit(&mut rng, class, img);
        if sigma > 0.0 {
            for v in img.iter_mut() {
                *v = (*v + rng.gaussian() * sigma).clamp(0.0, 1.0);
            }
        }
        labels.push(class);
    }
    Dataset { images, labels, dim: 784, classes: 10 }
}

/// Render one 3×32×32 CIFAR-like sample: class = hue (5) × orientation (2).
fn render_cifar(rng: &mut Rng, class: usize, out: &mut [f32]) {
    debug_assert_eq!(out.len(), 3 * 32 * 32);
    let hue = class % 5;
    let vertical = class >= 5;
    let period = 4 + rng.below(3);
    let phase = rng.below(period);
    // hue -> rgb weights
    let rgb: [f32; 3] = match hue {
        0 => [1.0, 0.1, 0.1],
        1 => [0.1, 1.0, 0.1],
        2 => [0.1, 0.1, 1.0],
        3 => [1.0, 1.0, 0.1],
        _ => [1.0, 0.1, 1.0],
    };
    for c in 0..3 {
        for y in 0..32 {
            for x in 0..32 {
                let coord = if vertical { x } else { y };
                let stripe = ((coord + phase) / period) % 2 == 0;
                let base = if stripe { rgb[c] } else { rgb[c] * 0.25 };
                out[(c * 32 + y) * 32 + x] = (base + rng.gaussian() * 0.15).clamp(0.0, 1.0);
            }
        }
    }
}

/// Generate `n` CIFAR-like samples (dim 3072, 10 classes, NCHW layout).
pub fn synth_cifar(n: usize, seed: u64) -> Dataset {
    let mut rng = Rng::new(seed);
    let dim = 3 * 32 * 32;
    let mut images = vec![0f32; n * dim];
    let mut labels = Vec::with_capacity(n);
    for i in 0..n {
        let class = i % 10;
        render_cifar(&mut rng, class, &mut images[i * dim..(i + 1) * dim]);
        labels.push(class);
    }
    Dataset { images, labels, dim, classes: 10 }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn digits_shape_and_determinism() {
        let a = synth_digits(20, 1);
        let b = synth_digits(20, 1);
        assert_eq!(a.images, b.images);
        assert_eq!(a.len(), 20);
        assert_eq!(a.dim, 784);
        // all classes present
        for c in 0..10 {
            assert!(a.labels.contains(&c));
        }
    }

    #[test]
    fn digits_values_in_unit_range() {
        let d = synth_digits(10, 2);
        assert!(d.images.iter().all(|&v| (0.0..=1.0).contains(&v)));
        // images are not blank
        for i in 0..10 {
            let s: f32 = d.image(i).iter().sum();
            assert!(s > 10.0, "image {i} nearly blank: sum {s}");
        }
    }

    #[test]
    fn classes_are_distinguishable() {
        // nearest-centroid classification on clean-ish data must beat chance
        let train = synth_digits(500, 3);
        let test = synth_digits(100, 4);
        let mut centroids = vec![vec![0f32; 784]; 10];
        let mut counts = [0usize; 10];
        for i in 0..train.len() {
            let c = train.labels[i];
            counts[c] += 1;
            for (j, &v) in train.image(i).iter().enumerate() {
                centroids[c][j] += v;
            }
        }
        for c in 0..10 {
            for v in centroids[c].iter_mut() {
                *v /= counts[c] as f32;
            }
        }
        let mut correct = 0;
        for i in 0..test.len() {
            let img = test.image(i);
            let mut best = (f32::INFINITY, 0usize);
            for (c, cent) in centroids.iter().enumerate() {
                let d: f32 = img.iter().zip(cent).map(|(a, b)| (a - b).powi(2)).sum();
                if d < best.0 {
                    best = (d, c);
                }
            }
            if best.1 == test.labels[i] {
                correct += 1;
            }
        }
        assert!(correct >= 50, "nearest-centroid got {correct}/100");
    }

    #[test]
    fn cifar_shape() {
        let d = synth_cifar(10, 5);
        assert_eq!(d.dim, 3072);
        assert!(d.images.iter().all(|&v| (0.0..=1.0).contains(&v)));
    }
}

//! TFC: the tiny fully-connected MNIST models of Table III
//! (three hidden layers of 64 neurons, quantized weights/activations).

use super::rng::Rng;
use crate::ir::{AttrValue, GraphBuilder, ModelGraph};
use crate::tensor::Tensor;
use anyhow::Result;

/// Dense layer parameters destined for a QONNX graph.
#[derive(Debug, Clone)]
pub struct DenseParams {
    /// `[in, out]` row-major weight matrix (float, pre-quantization).
    pub w: Tensor,
    /// optional `[out]` float bias added before the activation quantizer
    pub bias: Option<Tensor>,
    /// weight quantization scale
    pub w_scale: f32,
    /// activation quantization scale (None on the output layer)
    pub a_scale: Option<f32>,
}

/// Full TFC parameter set (4 dense layers: 784→64→64→64→10).
#[derive(Debug, Clone)]
pub struct TfcParams {
    pub layers: Vec<DenseParams>,
    pub weight_bits: u32,
    pub act_bits: u32,
}

impl TfcParams {
    /// Deterministic random initialization (untrained model).
    pub fn random(weight_bits: u32, act_bits: u32, seed: u64) -> TfcParams {
        let dims = [784usize, 64, 64, 64, 10];
        let mut rng = Rng::new(seed);
        let mut layers = Vec::new();
        for i in 0..4 {
            let (fi, fo) = (dims[i], dims[i + 1]);
            let w = Tensor::new(vec![fi, fo], rng.he_weights(fi * fo, fi));
            layers.push(DenseParams {
                w,
                bias: None,
                w_scale: 0.25,
                a_scale: if i < 3 { Some(0.25) } else { None },
            });
        }
        TfcParams { layers, weight_bits, act_bits }
    }
}

/// Build the TFC-wXaY QONNX graph.
///
/// Topology (Brevitas-export style): 8-bit input `Quant` → 4 × (`Quant`
/// weights → `MatMul`) with an activation `Quant`/`BipolarQuant` after the
/// first three. 1-bit weights/activations use `BipolarQuant` (the FINN
/// w1a1 convention).
pub fn tfc(params: &TfcParams) -> Result<ModelGraph> {
    let name = format!("TFC-w{}a{}", params.weight_bits, params.act_bits);
    let mut b = GraphBuilder::new(&name);
    b.input("x", vec![1, 784]);
    b.quant("x", "x_q", 1.0 / 255.0, 0.0, 8.0, false, false, "ROUND");
    let mut cur = "x_q".to_string();
    for (i, layer) in params.layers.iter().enumerate() {
        let w_name = format!("fc{i}_w");
        let wq_name = format!("fc{i}_wq");
        b.initializer(&w_name, layer.w.clone());
        if params.weight_bits == 1 {
            b.bipolar_quant(&w_name, &wq_name, layer.w_scale);
        } else {
            b.quant(&w_name, &wq_name, layer.w_scale, 0.0, params.weight_bits as f32, true, true, "ROUND");
        }
        let mm_name = format!("fc{i}_out");
        b.node("MatMul", &[&cur, &wq_name], &[&mm_name], &[]);
        cur = mm_name;
        if let Some(bias) = &layer.bias {
            let b_name = format!("fc{i}_bias");
            let add_name = format!("fc{i}_biased");
            b.initializer(&b_name, bias.clone());
            b.node("Add", &[&cur, &b_name], &[&add_name], &[]);
            cur = add_name;
        }
        if let Some(a_scale) = layer.a_scale {
            let aq_name = format!("act{i}_q");
            if params.act_bits == 1 {
                b.bipolar_quant(&cur, &aq_name, a_scale);
            } else {
                b.quant(&cur, &aq_name, a_scale, 0.0, params.act_bits as f32, true, false, "ROUND");
            }
            cur = aq_name;
        }
    }
    // stable output name
    b.node("Identity", &[&cur], &["logits"], &[]);
    b.output("logits", vec![1, 10]);
    let mut g = b.finish()?;
    g.doc = format!(
        "TFC {}-bit weight / {}-bit activation MLP (784-64-64-64-10), QONNX model zoo style",
        params.weight_bits, params.act_bits
    );
    // batch-friendly: the builder fixed batch 1; callers reshape
    let _ = AttrValue::Int(0);
    Ok(g)
}

/// Build TFC with a flexible batch dimension.
pub fn tfc_batch(params: &TfcParams, batch: usize) -> Result<ModelGraph> {
    let mut g = tfc(params)?;
    g.inputs[0].shape = Some(vec![batch, 784]);
    g.outputs[0].shape = Some(vec![batch, 10]);
    Ok(g)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::exec::execute_simple;
    use crate::metrics::analyze;
    use crate::transforms::cleanup;

    #[test]
    fn builds_all_table_iii_variants() {
        for (w, a) in [(1u32, 1u32), (1, 2), (2, 2)] {
            let g = tfc(&TfcParams::random(w, a, 1)).unwrap();
            g.validate().unwrap();
            let hist = g.op_histogram();
            assert_eq!(hist["MatMul"], 4, "TFC-w{w}a{a}");
            if w == 1 {
                assert!(hist["BipolarQuant"] >= 4);
            } else {
                assert!(hist["Quant"] >= 5); // input + 4 weights (+ acts)
            }
        }
    }

    #[test]
    fn table_iii_fc_metrics() {
        // Table III: TFC weights = MACs = 59008
        let mut g = tfc(&TfcParams::random(2, 2, 1)).unwrap();
        cleanup(&mut g).unwrap();
        let r = analyze(&g).unwrap();
        assert_eq!(r.macs(), 59_008);
        assert_eq!(r.weights(), 59_008);
        assert_eq!(r.total_weight_bits(), 118_016); // w2: Table III last col
        let g1 = {
            let mut g = tfc(&TfcParams::random(1, 1, 1)).unwrap();
            cleanup(&mut g).unwrap();
            g
        };
        assert_eq!(analyze(&g1).unwrap().total_weight_bits(), 59_008);
    }

    #[test]
    fn executes_end_to_end() {
        let g = tfc(&TfcParams::random(2, 2, 7)).unwrap();
        let x = Tensor::new(vec![1, 784], (0..784).map(|i| (i % 255) as f32 / 255.0).collect());
        let y = execute_simple(&g, &x).unwrap();
        assert_eq!(y.shape(), &[1, 10]);
        assert!(y.as_f32().unwrap().iter().all(|v| v.is_finite()));
    }

    #[test]
    fn batch_variant() {
        let g = tfc_batch(&TfcParams::random(1, 2, 7), 8).unwrap();
        let x = Tensor::zeros(vec![8, 784]);
        let y = execute_simple(&g, &x).unwrap();
        assert_eq!(y.shape(), &[8, 10]);
    }
}

//! PR-10 acceptance suite for compiled-plan artifacts (`.qpln`):
//!
//! * every zoo model round-trips byte-identically — float and
//!   streamlined tiers, batch-1 and batch-8 — through write → load,
//! * loading performs ZERO weight-panel re-packing (pointer provenance:
//!   every panel borrows from the artifact mapping),
//! * every corruption mode on a real compiled zoo artifact fails with
//!   its typed [`ArtifactError`] — never UB, never a panic,
//! * a structurally valid artifact with a tampered (re-signed) schedule
//!   loads fine but trips the static plan verifier (`verify --artifact`),
//! * the batcher serves an artifact-loaded engine byte-identically to an
//!   in-process-compiled engine, shards sharing one loaded mapping.

use qonnx::coordinator::{Batcher, BatcherConfig, InferenceEngine, PlannedEngine};
use qonnx::ir::ModelGraph;
use qonnx::plan::artifact::{self, format, ArtifactError};
use qonnx::plan::{ExecutionPlan, RunConfig, ShapeCheck};
use qonnx::tensor::Tensor;
use qonnx::testutil::random_tensor;
use qonnx::zoo::rng::Rng;
use qonnx::{transforms, zoo};
use std::path::PathBuf;

fn tmp(tag: &str) -> PathBuf {
    let safe: String =
        tag.chars().map(|c| if c.is_ascii_alphanumeric() { c } else { '_' }).collect();
    std::env::temp_dir().join(format!("qonnx_artrt_{}_{safe}.qpln", std::process::id()))
}

fn run_plan(plan: &ExecutionPlan<'_>, in_name: &str, x: &Tensor, out_name: &str) -> Tensor {
    let cfg = RunConfig { shape_check: ShapeCheck::FreeBatch, record_intermediates: false };
    plan.run_cfg(|n| (n == in_name).then_some(x), &cfg)
        .unwrap()
        .outputs
        .remove(out_name)
        .unwrap()
}

/// Write → load → compare one compiled tier of one model: schedule
/// identical, zero re-packing, outputs byte-identical at batch 1 and 8.
fn assert_tier_roundtrips(g: &ModelGraph, label: &str) {
    let plan = ExecutionPlan::compile(g).unwrap_or_else(|e| panic!("{label}: compile: {e:#}"));
    let path = tmp(label);
    artifact::write_artifact(&plan, g, None, &path)
        .unwrap_or_else(|e| panic!("{label}: write: {e:#}"));
    let loaded = artifact::read_artifact(&path).unwrap_or_else(|e| panic!("{label}: load: {e}"));

    // the frozen schedule, counters, and slot tables survived verbatim
    assert_eq!(loaded.plan.summary(), plan.summary(), "{label}: schedule changed");

    // zero weight-panel re-packing: every PackedB/PackedBi8 panel (and
    // SIMD tile) borrows straight from the artifact mapping
    let zc = loaded.zero_copy_report();
    assert_eq!(zc.owned_panels, 0, "{label}: re-packed panels: {zc:?}");
    if plan.packed_count() + plan.quant_kernel_count() > 0 {
        assert!(zc.mapped_panels >= 1, "{label}: no mapped panels: {zc:?}");
        assert!(zc.mapped_bytes > 0, "{label}: {zc:?}");
    }

    let in_name = g
        .inputs
        .iter()
        .find(|vi| !g.initializers.contains_key(&vi.name))
        .expect("graph input")
        .name
        .clone();
    let mut in_shape = g
        .inputs
        .iter()
        .find(|vi| vi.name == in_name)
        .and_then(|vi| vi.shape.clone())
        .expect("input shape");
    let out_name = g.outputs[0].name.clone();

    // batch-8 is part of the contract for the serving models; only a
    // plan that *declares* batch blockers may skip it
    let batches: &[usize] =
        if plan.batch_blockers().is_empty() { &[1, 8] } else { &[1] };
    let mut rng = Rng::new(97);
    for &n in batches {
        in_shape[0] = n;
        let x = random_tensor(&mut rng, in_shape.clone(), 0.0, 1.0);
        let y_compiled = run_plan(&plan, &in_name, &x, &out_name);
        let y_loaded = run_plan(&loaded.plan, &in_name, &x, &out_name);
        assert_eq!(y_compiled, y_loaded, "{label}: batch {n} diverged");
    }
    std::fs::remove_file(&path).ok();
}

/// The tentpole acceptance case: EVERY zoo model round-trips through an
/// artifact byte-identically, float tier and (where the model lowers)
/// streamlined integer tier, batch-1 and batch-8.
#[test]
fn every_zoo_model_roundtrips_byte_identical() {
    for name in zoo::ZOO_NAMES {
        let mut g = zoo::build(name, 1, 32).unwrap();
        transforms::cleanup(&mut g).unwrap();

        let fplan = ExecutionPlan::compile(&g).unwrap();
        if name.starts_with("TFC") || name.starts_with("CNV") {
            assert!(
                fplan.batch_blockers().is_empty(),
                "'{name}' must serve batches:\n{}",
                fplan.summary()
            );
        }
        drop(fplan);
        assert_tier_roundtrips(&g, &format!("{name} (float)"));

        let sl = qonnx::streamline::try_streamline(&g).unwrap();
        if sl.report.ok {
            assert_tier_roundtrips(&sl.graph, &format!("{name} (streamlined)"));
        }
    }
}

/// Satellite 1: every corruption mode on a REAL compiled zoo artifact is
/// a typed error. Table-driven: (label, byte-level mutation, expected
/// variant matcher).
#[test]
fn corrupt_zoo_artifact_fails_typed_never_ub() {
    let mut g = zoo::build("TFC-w2a2", 1, 32).unwrap();
    transforms::cleanup(&mut g).unwrap();
    let path = tmp("corrupt_src");
    PlannedEngine::compile_to_artifact(&g, &path).unwrap();
    let pristine = std::fs::read(&path).unwrap();
    assert!(pristine.len() > format::HEADER_LEN + 6 * format::ENTRY_LEN);

    type Mutate = fn(&mut Vec<u8>);
    type Check = fn(&ArtifactError) -> bool;
    let cases: &[(&str, Mutate, Check)] = &[
        (
            "truncated inside the header",
            |b| b.truncate(format::HEADER_LEN / 2),
            |e| matches!(e, ArtifactError::Truncated { .. }),
        ),
        (
            "truncated inside the section table",
            |b| b.truncate(format::HEADER_LEN + format::ENTRY_LEN / 2),
            |e| matches!(e, ArtifactError::Truncated { .. }),
        ),
        (
            "truncated halfway through the payload",
            |b| {
                let half = b.len() / 2;
                b.truncate(half);
            },
            |e| matches!(e, ArtifactError::Truncated { .. }),
        ),
        (
            "single flipped byte in the largest (weight) section",
            |b| {
                // find the longest section via the table so the flip is
                // guaranteed to land inside CRC-covered payload bytes
                let mut best = (0u64, 0u64);
                for i in 0..6 {
                    let e = format::HEADER_LEN + i * format::ENTRY_LEN;
                    let off = u64::from_ne_bytes(b[e + 8..e + 16].try_into().unwrap());
                    let len = u64::from_ne_bytes(b[e + 16..e + 24].try_into().unwrap());
                    if len > best.1 {
                        best = (off, len);
                    }
                }
                let i = (best.0 + best.1 - 1) as usize;
                b[i] ^= 0x40;
            },
            |e| matches!(e, ArtifactError::ChecksumMismatch { .. }),
        ),
        (
            "single flipped byte early in the META payload",
            |b| {
                b[format::HEADER_LEN + 6 * format::ENTRY_LEN + 64] ^= 0x01;
            },
            |e| matches!(e, ArtifactError::ChecksumMismatch { .. }),
        ),
        (
            "wrong magic",
            |b| b[0] ^= 0xff,
            |e| matches!(e, ArtifactError::BadMagic),
        ),
        (
            "format version skew",
            |b| b[8..12].copy_from_slice(&99u32.to_ne_bytes()),
            |e| matches!(e, ArtifactError::VersionSkew { found: 99, .. }),
        ),
        (
            "misaligned section offset",
            |b| {
                // entry 0's offset field (bytes 8..16 of the entry): +1
                // breaks the 64-byte zero-copy alignment contract
                let off = format::HEADER_LEN + 8;
                let mut v = u64::from_ne_bytes(b[off..off + 8].try_into().unwrap());
                v += 1;
                b[off..off + 8].copy_from_slice(&v.to_ne_bytes());
            },
            |e| matches!(e, ArtifactError::MisalignedSection { .. }),
        ),
        (
            "SIMD ISA mismatch",
            |b| {
                let mut isa = [0u8; format::ISA_NAME_LEN];
                isa[..5].copy_from_slice(b"sse99");
                b[20..20 + format::ISA_NAME_LEN].copy_from_slice(&isa);
            },
            |e| matches!(e, ArtifactError::IsaMismatch { .. }),
        ),
    ];

    let victim = tmp("corrupt_victim");
    for (label, mutate, check) in cases {
        let mut bytes = pristine.clone();
        mutate(&mut bytes);
        std::fs::write(&victim, &bytes).unwrap();
        let err = artifact::read_artifact(&victim)
            .err()
            .unwrap_or_else(|| panic!("{label}: corrupt artifact loaded"));
        assert!(check(&err), "{label}: wrong error variant: {err}");
        assert!(!err.to_string().is_empty(), "{label}");
    }

    // and the pristine bytes still load + serve after all that
    std::fs::write(&victim, &pristine).unwrap();
    let loaded = artifact::read_artifact(&victim).unwrap();
    assert_eq!(loaded.zero_copy_report().owned_panels, 0);
    std::fs::remove_file(&path).ok();
    std::fs::remove_file(&victim).ok();
}

/// Satellite 2: checksums cannot catch a *re-signed* tamper — but the
/// static verifier re-proves the deserialized schedule against the
/// embedded graph and trips on it (`qonnx verify --artifact`).
#[test]
fn resigned_schedule_tamper_trips_static_verifier() {
    let mut g = zoo::build("TFC-w1a1", 1, 32).unwrap();
    transforms::cleanup(&mut g).unwrap();
    let path = tmp("mutate");
    PlannedEngine::compile_to_artifact(&g, &path).unwrap();

    // untampered: the artifact plan verifies clean against its graph
    let clean = artifact::read_artifact(&path).unwrap();
    let graph = clean.graph().unwrap();
    let report = qonnx::verify::verify_plan(&clean.plan, &graph);
    assert!(!report.has_errors(), "pristine artifact must verify:\n{}", report.render());

    // swap first/last schedule steps and re-sign every checksum: the
    // file is structurally valid, so loading succeeds...
    artifact::mutate_schedule(&path).unwrap();
    let tampered = artifact::read_artifact(&path).unwrap();
    // ...but the verifier refuses the plan
    let graph = tampered.graph().unwrap();
    let report = qonnx::verify::verify_plan(&tampered.plan, &graph);
    assert!(
        report.has_errors(),
        "swapped schedule must trip the verifier:\n{}",
        report.render()
    );
    std::fs::remove_file(&path).ok();
}

/// Satellite 4 (in-process half of the CI job): `serve --artifact`
/// semantics — the batcher drives shards that share ONE loaded artifact
/// and answers byte-identically to an in-process-compiled engine.
#[test]
fn batcher_serves_artifact_byte_identical_to_compiled_engine() {
    for name in ["TFC-w2a2", "CNV-w1a2"] {
        let mut g = zoo::build(name, 1, 32).unwrap();
        transforms::cleanup(&mut g).unwrap();
        let path = tmp(&format!("serve_{name}"));
        let mut compiled = PlannedEngine::compile_to_artifact(&g, &path).unwrap();

        let template = PlannedEngine::from_artifact(&path).unwrap();
        assert_eq!(template.streamlined(), compiled.streamlined(), "{name}");
        let in_dim = compiled.input_dim();
        let plan = template.plan_handle();
        let batcher = Batcher::start_sharded(
            move || Ok(Box::new(template.share()) as Box<dyn InferenceEngine>),
            BatcherConfig::default(),
            2,
        )
        .unwrap();
        // both shards came up on Arc views of the ONE loaded plan
        assert_eq!(std::sync::Arc::strong_count(&plan), 4);

        let input: Vec<f32> = (0..in_dim).map(|i| (i % 29) as f32 / 29.0).collect();
        let served = batcher.infer(input.clone()).unwrap();
        let want = compiled.infer_batch(&Tensor::new(vec![1, in_dim], input)).unwrap();
        assert_eq!(served, want.as_f32().unwrap(), "{name}: served != compiled");
        batcher.shutdown();
        std::fs::remove_file(&path).ok();
    }
}

//! Integration tests: format lowering equivalences across whole zoo
//! models — the executable version of the paper's §IV/§VI claims.

use qonnx::exec::{self, ExecOptions};
use qonnx::tensor::Tensor;
use qonnx::testutil::{assert_close, for_all_seeds, random_tensor};
use qonnx::transforms;
use qonnx::zoo::{cnv, tfc, TfcParams};
use std::collections::BTreeMap;

fn run(g: &qonnx::ir::ModelGraph, x: &Tensor) -> Tensor {
    exec::execute_simple(g, x).unwrap()
}

fn run_standard_only(g: &qonnx::ir::ModelGraph, x: &Tensor) -> Tensor {
    let mut m = BTreeMap::new();
    m.insert(g.inputs[0].name.clone(), x.clone());
    let opts = ExecOptions { standard_onnx_only: true, ..Default::default() };
    exec::execute_with(g, &m, &opts)
        .unwrap()
        .outputs
        .into_values()
        .next()
        .unwrap()
}

/// TFC-w2a2 and -w1a2* lower to QCDQ and run bit-exact on a backend with
/// no QONNX support (§IV). (*w1 weights are BipolarQuant → not QCDQ-able,
/// so only multi-bit variants lower.)
#[test]
fn tfc_qcdq_standard_backend_equivalence() {
    for (w, a) in [(2u32, 2u32), (4, 4), (2, 4)] {
        let g = tfc(&TfcParams::random(w, a, 7)).unwrap();
        let mut qcdq = g.clone();
        transforms::lower_to_qcdq(&mut qcdq).unwrap();
        for_all_seeds(5, |rng| {
            let x = random_tensor(rng, vec![1, 784], 0.0, 1.0);
            let y0 = run(&g, &x);
            let y1 = run_standard_only(&qcdq, &x);
            assert_eq!(y0, y1, "w{w}a{a}");
        });
    }
}

/// QCDQ raising is the exact inverse of lowering on TFC.
#[test]
fn tfc_qcdq_roundtrip_preserves_semantics() {
    let g = tfc(&TfcParams::random(3, 3, 9)).unwrap();
    let mut rt = g.clone();
    transforms::lower_to_qcdq(&mut rt).unwrap();
    transforms::raise_qcdq_to_qonnx(&mut rt).unwrap();
    assert!(!rt.op_histogram().contains_key("QuantizeLinear"));
    for_all_seeds(5, |rng| {
        let x = random_tensor(rng, vec![1, 784], 0.0, 1.0);
        assert_eq!(run(&g, &x), run(&rt, &x));
    });
}

/// FINN conversion (weights folded + MultiThreshold) is bit-exact on every
/// TFC variant including the bipolar one.
#[test]
fn tfc_finn_conversion_equivalence() {
    for (w, a) in [(1u32, 1u32), (1, 2), (2, 2)] {
        let g = tfc(&TfcParams::random(w, a, 11)).unwrap();
        let mut finn = g.clone();
        transforms::cleanup(&mut finn).unwrap();
        transforms::convert_to_finn(&mut finn).unwrap();
        let h = finn.op_histogram();
        assert!(h.contains_key("MultiThreshold"), "w{w}a{a}");
        assert!(!h.contains_key("Quant") && !h.contains_key("BipolarQuant"), "w{w}a{a}");
        for_all_seeds(3, |rng| {
            let x = random_tensor(rng, vec![1, 784], 0.0, 1.0);
            assert_eq!(run(&g, &x), run(&finn, &x), "w{w}a{a}");
        });
    }
}

/// FINN conversion on the full CNV conv net.
#[test]
fn cnv_finn_conversion_equivalence() {
    let mut g = cnv(2, 2, 13, false).unwrap();
    transforms::cleanup(&mut g).unwrap();
    let mut finn = g.clone();
    transforms::convert_to_finn(&mut finn).unwrap();
    let mut rng = qonnx::zoo::rng::Rng::new(99);
    let x = random_tensor(&mut rng, vec![1, 3, 32, 32], 0.0, 1.0);
    assert_close(&run(&g, &x), &run(&finn, &x), 1e-4);
}

/// hls4ml ingestion on TFC: integers + propagated scales, numerically close.
#[test]
fn tfc_hls4ml_equivalence() {
    let g = tfc(&TfcParams::random(4, 4, 17)).unwrap();
    let mut h = g.clone();
    transforms::cleanup(&mut h).unwrap();
    transforms::hls4ml_ingest(&mut h).unwrap();
    // constant-path Quants are gone; data-flow (activation) Quants remain
    // explicit, exactly as hls4ml keeps them (paper §VI-C)
    for n in h.nodes.iter().filter(|n| n.op_type == "Quant") {
        assert!(
            !h.initializers.contains_key(&n.inputs[0]),
            "weight Quant '{}' survived ingestion",
            n.name
        );
    }
    for_all_seeds(3, |rng| {
        let x = random_tensor(rng, vec![1, 784], 0.0, 1.0);
        assert_close(&run(&g, &x), &run(&h, &x), 1e-3);
    });
}

/// Channels-last conversion on CNV preserves outputs exactly (Fig. 3).
#[test]
fn cnv_channels_last_equivalence() {
    let mut g = cnv(1, 2, 21, false).unwrap();
    transforms::cleanup(&mut g).unwrap();
    let mut cl = g.clone();
    transforms::to_channels_last(&mut cl).unwrap();
    let mut rng = qonnx::zoo::rng::Rng::new(5);
    let x = random_tensor(&mut rng, vec![1, 3, 32, 32], 0.0, 1.0);
    let y0 = run(&g, &x);
    let mut m = BTreeMap::new();
    m.insert("x".to_string(), qonnx::tensor::nchw_to_nhwc(&x).unwrap());
    let y1 = exec::execute(&cl, &m).unwrap().outputs.into_values().next().unwrap();
    assert_eq!(y0, y1);
}

/// The full chain: raw export -> cleanup -> channels-last -> FINN, all
/// equivalent (the complete Fig. 1-3 + §VI-D pipeline on one model).
#[test]
fn cnv_full_pipeline_chain() {
    let raw = cnv(2, 2, 31, true).unwrap();
    let mut rng = qonnx::zoo::rng::Rng::new(77);
    let x = random_tensor(&mut rng, vec![1, 3, 32, 32], 0.0, 1.0);
    let y_raw = run(&raw, &x);

    let mut g = raw.clone();
    transforms::cleanup(&mut g).unwrap();
    assert_eq!(y_raw, run(&g, &x));

    let mut finn = g.clone();
    transforms::convert_to_finn(&mut finn).unwrap();
    assert_close(&y_raw, &run(&finn, &x), 1e-4);

    let mut cl = finn.clone();
    transforms::to_channels_last(&mut cl).unwrap();
    let mut m = BTreeMap::new();
    m.insert("x".to_string(), qonnx::tensor::nchw_to_nhwc(&x).unwrap());
    let y_cl = exec::execute(&cl, &m).unwrap().outputs.into_values().next().unwrap();
    assert_close(&y_raw, &y_cl, 1e-4);
}

/// Serialization round-trip through disk preserves lowering results.
#[test]
fn lowered_graphs_serialize() {
    let g = tfc(&TfcParams::random(2, 2, 41)).unwrap();
    for (tag, f) in [
        ("qcdq", transforms::lower_to_qcdq as fn(&mut qonnx::ir::ModelGraph) -> anyhow::Result<bool>),
        ("finn", transforms::convert_to_finn),
        ("hls4ml", transforms::hls4ml_ingest),
    ] {
        let mut lowered = g.clone();
        transforms::cleanup(&mut lowered).unwrap();
        f(&mut lowered).unwrap();
        let path = std::env::temp_dir().join(format!("qonnx_lowering_{tag}.qonnx.json"));
        qonnx::ir::json::save_model(&lowered, path.to_str().unwrap()).unwrap();
        let back = qonnx::ir::json::load_model(path.to_str().unwrap()).unwrap();
        assert_eq!(lowered, back, "{tag}");
        let mut rng = qonnx::zoo::rng::Rng::new(1);
        let x = random_tensor(&mut rng, vec![1, 784], 0.0, 1.0);
        assert_eq!(run(&lowered, &x), run(&back, &x), "{tag}");
    }
}

//! End-to-end pipeline integration: QAT training → QONNX export → cleanup
//! → accuracy through the executor → lowering — the automated version of
//! examples/e2e_tfc_pipeline.rs (smaller budget so `cargo test` stays
//! fast), plus PJRT parity when artifacts are present.

use qonnx::coordinator::{Batcher, BatcherConfig, InferenceEngine, PjrtEngine, ReferenceEngine};
use qonnx::exec;
use qonnx::ir::json::load_model;
use qonnx::runtime::{artifacts_dir, PjrtRuntime};
use qonnx::tensor::Tensor;
use qonnx::training::{train_mlp, QatConfig};
use qonnx::transforms;
use qonnx::zoo::synth_digits;
use std::collections::BTreeMap;

#[test]
fn train_export_execute_accuracy() {
    let train = synth_digits(600, 300);
    let test = synth_digits(200, 301);
    let mut cfg = QatConfig::tfc(2, 2);
    cfg.epochs = 10;
    let mut model = train_mlp(&train, &cfg).unwrap();
    let internal = model.accuracy(&test);
    assert!(internal > 80.0, "internal accuracy {internal}");

    let mut g = model.to_qonnx(test.len()).unwrap();
    transforms::cleanup(&mut g).unwrap();
    let mut inputs = BTreeMap::new();
    inputs.insert("x".to_string(), Tensor::new(vec![test.len(), 784], test.images.clone()));
    let out = exec::execute(&g, &inputs).unwrap();
    let logits = out.outputs.values().next().unwrap().as_f32().unwrap().to_vec();
    let mut correct = 0;
    for i in 0..test.len() {
        let row = &logits[i * 10..(i + 1) * 10];
        let pred = row.iter().enumerate().max_by(|a, b| a.1.partial_cmp(b.1).unwrap()).unwrap().0;
        if pred == test.labels[i] {
            correct += 1;
        }
    }
    let graph_acc = 100.0 * correct as f32 / test.len() as f32;
    assert!(
        (graph_acc - internal).abs() < 8.0,
        "graph accuracy {graph_acc} vs internal {internal}"
    );
}

/// Python-exported QONNX JSON (shared weights with the PJRT artifact)
/// executes identically in the Rust reference executor and through PJRT —
/// the cross-language, cross-engine parity check.
#[test]
fn pjrt_vs_reference_executor_parity() {
    let stem = artifacts_dir().join("tfc_w2a2");
    if !stem.with_extension("hlo.txt").exists() {
        eprintln!("skipping: artifacts not built");
        return;
    }
    let rt = PjrtRuntime::cpu().unwrap();
    let (compiled, meta) = rt.load_artifact(&stem).unwrap();
    let mut py_graph = load_model(artifacts_dir().join("tfc_w2a2.qonnx.json").to_str().unwrap()).unwrap();
    transforms::cleanup(&mut py_graph).unwrap();
    let mut engine = ReferenceEngine::new(py_graph).unwrap();
    let x = Tensor::new(vec![8, 784], meta.probe_input.clone());
    let y_ref = engine.infer_batch(&x).unwrap();
    let y_pjrt = compiled.execute(&x).unwrap();
    for (a, b) in y_ref.as_f32().unwrap().iter().zip(y_pjrt.as_f32().unwrap()) {
        assert!((a - b).abs() < 1e-3, "{a} vs {b}");
    }
}

/// All three exported artifact variants pass their build-time probes.
#[test]
fn all_artifacts_self_check() {
    let dir = artifacts_dir();
    if !dir.join("tfc_w1a1.hlo.txt").exists() {
        eprintln!("skipping: artifacts not built");
        return;
    }
    let rt = PjrtRuntime::cpu().unwrap();
    for tag in ["tfc_w1a1", "tfc_w1a2", "tfc_w2a2"] {
        let (model, meta) = rt.load_artifact(&dir.join(tag)).unwrap();
        let err = model.self_check(&meta).unwrap();
        assert!(err < 1e-3, "{tag}: probe err {err}");
    }
}

/// Serving through the batcher returns the same answers as direct PJRT
/// execution, under concurrency.
#[test]
fn batcher_pjrt_consistency() {
    let stem = artifacts_dir().join("tfc_w2a2");
    if !stem.with_extension("hlo.txt").exists() {
        eprintln!("skipping: artifacts not built");
        return;
    }
    let stem2 = stem.clone();
    let batcher = Batcher::start(
        move || {
            let rt = PjrtRuntime::cpu()?;
            Ok(Box::new(PjrtEngine::load(&rt, &stem2)?) as Box<dyn InferenceEngine>)
        },
        BatcherConfig::default(),
    )
    .unwrap();
    let rt = PjrtRuntime::cpu().unwrap();
    let (compiled, _) = rt.load_artifact(&stem).unwrap();
    let input: Vec<f32> = (0..784).map(|i| (i % 9) as f32 / 9.0).collect();
    let served = batcher.infer(input.clone()).unwrap();
    let mut batch = vec![0f32; 8 * 784];
    batch[..784].copy_from_slice(&input);
    let direct = compiled.execute(&Tensor::new(vec![8, 784], batch)).unwrap();
    for (a, b) in served.iter().zip(&direct.as_f32().unwrap()[..10]) {
        assert!((a - b).abs() < 1e-6);
    }
}

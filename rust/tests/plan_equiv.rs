//! Plan-vs-interpreter equivalence: the compiled ExecutionPlan must be
//! observationally identical to the name-keyed reference interpreter —
//! across the model zoo (TFC, CNV, keraslike), the `standard_onnx_only`
//! restriction, error reporting for missing/mis-shaped inputs, and the
//! QCDQ lower→raise round-trip.

use qonnx::coordinator::{Batcher, BatcherConfig, InferenceEngine, PlannedEngine};
use qonnx::exec::{self, ExecOptions};
use qonnx::ir::{AttrValue, GraphBuilder, ModelGraph};
use qonnx::plan::{ExecutionPlan, PlanOptions, RunConfig, ShapeCheck};
use qonnx::tensor::{DType, Tensor};
use qonnx::testutil::random_tensor;
use qonnx::transforms;
use qonnx::zoo::{self, keras_to_qonnx, rng::Rng, tfc, KerasModel, TfcParams};
use std::collections::BTreeMap;
use std::sync::Arc;

fn random_inputs(g: &ModelGraph, seed: u64) -> BTreeMap<String, Tensor> {
    let mut rng = Rng::new(seed);
    let mut m = BTreeMap::new();
    for vi in &g.inputs {
        if g.initializers.contains_key(&vi.name) {
            continue;
        }
        let shape = vi.shape.clone().expect("test graphs declare input shapes");
        m.insert(vi.name.clone(), random_tensor(&mut rng, shape, 0.0, 1.0));
    }
    m
}

/// Interpreter, one-shot plan wrapper, and a reused compiled plan must
/// produce byte-identical outputs.
fn assert_equivalent(g: &ModelGraph, inputs: &BTreeMap<String, Tensor>) {
    let interp = exec::interpret(g, inputs).unwrap();
    let plan = ExecutionPlan::compile(g).unwrap();
    let planned = plan.run(inputs).unwrap();
    assert_eq!(interp.outputs, planned, "plan != interpreter on '{}'", g.name);
    let wrapper = exec::execute(g, inputs).unwrap();
    assert_eq!(interp.outputs, wrapper.outputs, "execute() wrapper diverged on '{}'", g.name);
}

#[test]
fn tfc_variants_match_raw_and_cleaned() {
    for name in ["TFC-w2a2", "TFC-w1a1", "TFC-w1a2"] {
        let g = zoo::build(name, 1, 32).unwrap();
        assert_equivalent(&g, &random_inputs(&g, 11));
        let mut cleaned = g.clone();
        transforms::cleanup(&mut cleaned).unwrap();
        assert_equivalent(&cleaned, &random_inputs(&cleaned, 11));
    }
}

#[test]
fn cnv_matches() {
    let mut g = zoo::build("CNV-w1a1", 1, 32).unwrap();
    transforms::cleanup(&mut g).unwrap();
    let inputs = random_inputs(&g, 5);
    let interp = exec::interpret(&g, &inputs).unwrap();
    let planned = ExecutionPlan::compile(&g).unwrap().run(&inputs).unwrap();
    assert_eq!(interp.outputs, planned);
}

#[test]
fn keraslike_matches() {
    let g = keras_to_qonnx(&KerasModel::fig4_example(), 3).unwrap();
    assert_equivalent(&g, &random_inputs(&g, 7));
}

#[test]
fn standard_onnx_only_parity() {
    let g = tfc(&TfcParams::random(2, 2, 7)).unwrap();
    let inputs = random_inputs(&g, 3);
    let opts = ExecOptions { standard_onnx_only: true, ..Default::default() };

    // QONNX graph: both executors reject with the same diagnosis
    let e1 = exec::interpret_with(&g, &inputs, &opts).unwrap_err().to_string();
    let e2 = exec::execute_with(&g, &inputs, &opts).unwrap_err().to_string();
    let popts = PlanOptions { standard_onnx_only: true, ..Default::default() };
    let e3 = ExecutionPlan::compile_with(&g, &popts).unwrap_err().to_string();
    for e in [&e1, &e2, &e3] {
        assert!(e.contains("not a standard ONNX op"), "{e}");
    }

    // QCDQ-lowered graph: both run on the restricted backend, identically
    let mut qcdq = g.clone();
    transforms::lower_to_qcdq(&mut qcdq).unwrap();
    let y_interp = exec::interpret_with(&qcdq, &inputs, &opts).unwrap();
    let y_plan = exec::execute_with(&qcdq, &inputs, &opts).unwrap();
    assert_eq!(y_interp.outputs, y_plan.outputs);
    // and the restricted result matches the unrestricted QONNX original
    let y_orig = exec::interpret(&g, &inputs).unwrap();
    let (a, b) = (y_orig.outputs.values().next().unwrap(), y_plan.outputs.values().next().unwrap());
    assert_eq!(a, b, "QCDQ-on-stock-backend must be bit-exact vs QONNX");
}

#[test]
fn missing_input_and_shape_mismatch_error_parity() {
    let g = tfc(&TfcParams::random(2, 2, 9)).unwrap();

    let empty = BTreeMap::new();
    let e_i = exec::interpret(&g, &empty).unwrap_err().to_string();
    let e_p = exec::execute(&g, &empty).unwrap_err().to_string();
    assert!(e_i.contains("missing input tensor"), "{e_i}");
    assert!(e_p.contains("missing input tensor"), "{e_p}");

    let mut bad = BTreeMap::new();
    bad.insert(g.inputs[0].name.clone(), Tensor::zeros(vec![2, 784]));
    let e_i = exec::interpret(&g, &bad).unwrap_err().to_string();
    let e_p = exec::execute(&g, &bad).unwrap_err().to_string();
    assert!(e_i.contains("does not match declared"), "{e_i}");
    assert!(e_p.contains("does not match declared"), "{e_p}");
}

/// `lower_qcdq` → `raise_qcdq` round-trip runs identically through both
/// executors and reproduces the original model bit-exactly.
#[test]
fn qcdq_roundtrip_through_both_executors() {
    let g = tfc(&TfcParams::random(3, 3, 13)).unwrap();
    let mut rt = g.clone();
    transforms::lower_to_qcdq(&mut rt).unwrap();
    transforms::raise_qcdq_to_qonnx(&mut rt).unwrap();
    assert!(!rt.op_histogram().contains_key("QuantizeLinear"));
    let inputs = random_inputs(&g, 21);
    let y_orig = exec::interpret(&g, &inputs).unwrap().outputs;
    let y_rt_interp = exec::interpret(&rt, &inputs).unwrap().outputs;
    let plan = ExecutionPlan::compile(&rt).unwrap();
    let y_rt_plan = plan.run(&inputs).unwrap();
    // outputs keep their names through the round-trip, so compare values
    let a: Vec<&Tensor> = y_orig.values().collect();
    let b: Vec<&Tensor> = y_rt_interp.values().collect();
    let c: Vec<&Tensor> = y_rt_plan.values().collect();
    assert_eq!(a, b, "interpreter: round-trip changed semantics");
    assert_eq!(b, c, "plan: round-trip changed semantics");
}

/// The batcher serves a zoo model natively through the PlannedEngine and
/// returns the same answers as direct plan execution.
#[test]
fn batcher_serves_planned_engine() {
    let batcher = Batcher::start(
        || Ok(Box::new(PlannedEngine::from_zoo("TFC-w2a2")?) as Box<dyn InferenceEngine>),
        BatcherConfig::default(),
    )
    .unwrap();
    let input: Vec<f32> = (0..784).map(|i| (i % 9) as f32 / 9.0).collect();
    let served = batcher.infer(input.clone()).unwrap();
    assert_eq!(served.len(), 10);

    let mut direct = PlannedEngine::from_zoo("TFC-w2a2").unwrap();
    let y = direct.infer_batch(&Tensor::new(vec![1, 784], input)).unwrap();
    assert_eq!(served, y.as_f32().unwrap());
}

/// Interpreter, packed plan, and generic (specialize=off) plan must be
/// bit-identical; the packed plan must actually use packed kernels.
fn assert_packed_equivalent(g: &ModelGraph, inputs: &BTreeMap<String, Tensor>, min_packed: usize) {
    let interp = exec::interpret(g, inputs).unwrap();
    let packed = ExecutionPlan::compile(g).unwrap();
    assert!(
        packed.packed_count() >= min_packed,
        "expected >= {min_packed} packed kernels on '{}':\n{}",
        g.name,
        packed.summary()
    );
    let got = packed.run(inputs).unwrap();
    assert_eq!(interp.outputs, got, "packed plan != interpreter on '{}'", g.name);
    let generic_opts = PlanOptions { specialize: false, ..Default::default() };
    let generic = ExecutionPlan::compile_with(g, &generic_opts).unwrap();
    assert_eq!(generic.packed_count(), 0);
    assert_eq!(generic.run(inputs).unwrap(), got, "generic plan != packed plan on '{}'", g.name);
}

/// Grouped and depthwise Conv (with bias) through PackedConv: plan,
/// generic plan, and interpreter bit-match.
#[test]
fn grouped_and_depthwise_conv_match_through_packed_kernels() {
    let mut rng = Rng::new(42);
    for (channels, group, m) in [(4usize, 2usize, 6usize), (3, 3, 3), (8, 4, 8)] {
        let mut b = GraphBuilder::new(&format!("conv-g{group}"));
        b.input("x", vec![2, channels, 6, 6]);
        let cg = channels / group;
        b.initializer(
            "w",
            random_tensor(&mut rng, vec![m, cg, 3, 3], -1.0, 1.0),
        );
        b.initializer("bias", random_tensor(&mut rng, vec![m], -0.5, 0.5));
        b.node(
            "Conv",
            &["x", "w", "bias"],
            &["y"],
            &[
                ("kernel_shape", AttrValue::Ints(vec![3, 3])),
                ("pads", AttrValue::Ints(vec![1, 1, 1, 1])),
                ("group", AttrValue::Int(group as i64)),
            ],
        );
        b.output("y", vec![2, m, 6, 6]);
        let g = b.finish().unwrap();
        assert_packed_equivalent(&g, &random_inputs(&g, 13), 1);
    }
}

/// Gemm with every attribute combination (transA/transB/alpha/beta,
/// constant and runtime C) through PackedGemm.
#[test]
fn gemm_attribute_combinations_match_through_packed_kernels() {
    let mut rng = Rng::new(7);
    for (trans_a, trans_b, alpha, beta) in [
        (0i64, 0i64, 1.0f32, 1.0f32),
        (1, 0, 1.0, 1.0),
        (0, 1, 2.5, 0.5),
        (1, 1, 0.75, 3.0),
    ] {
        let (m, k, n) = (3usize, 5usize, 4usize);
        let mut b = GraphBuilder::new("gemm-attrs");
        b.input("a", if trans_a != 0 { vec![k, m] } else { vec![m, k] });
        let b_shape = if trans_b != 0 { vec![n, k] } else { vec![k, n] };
        b.initializer("w", random_tensor(&mut rng, b_shape, -2.0, 2.0));
        b.initializer("c", random_tensor(&mut rng, vec![1, n], -1.0, 1.0));
        b.node(
            "Gemm",
            &["a", "w", "c"],
            &["y"],
            &[
                ("transA", AttrValue::Int(trans_a)),
                ("transB", AttrValue::Int(trans_b)),
                ("alpha", AttrValue::Float(alpha)),
                ("beta", AttrValue::Float(beta)),
            ],
        );
        b.output("y", vec![m, n]);
        let g = b.finish().unwrap();
        assert_packed_equivalent(&g, &random_inputs(&g, 19), 1);
    }

    // runtime C: B constant but C a graph input — still packed
    let (m, k, n) = (2usize, 6usize, 3usize);
    let mut b = GraphBuilder::new("gemm-runtime-c");
    b.input("a", vec![m, k]);
    b.input("c", vec![m, n]);
    b.initializer("w", random_tensor(&mut rng, vec![k, n], -1.0, 1.0));
    b.node("Gemm", &["a", "w", "c"], &["y"], &[("beta", AttrValue::Float(2.0))]);
    b.output("y", vec![m, n]);
    let g = b.finish().unwrap();
    assert_packed_equivalent(&g, &random_inputs(&g, 23), 1);
}

/// The zoo models exercise PackedConv/PackedMatMul + epilogue fusion at
/// scale; re-assert bit equality with the packed-kernel counters checked.
#[test]
fn zoo_models_run_packed_and_match() {
    let g = zoo::build("TFC-w2a2", 1, 32).unwrap();
    assert_packed_equivalent(&g, &random_inputs(&g, 31), 3);
    let keras = keras_to_qonnx(&KerasModel::fig4_example(), 3).unwrap();
    assert_packed_equivalent(&keras, &random_inputs(&keras, 37), 1);
}

/// CNV through the batcher via the NCHW edge adapter — the
/// `serve --zoo CNV-w2a2` path. `from_zoo` now serves the streamlined
/// integer plan, so the byte-exact reference is the *streamlined* graph
/// through the float interpreter; the original float graph is matched
/// within the documented output-edge tolerance.
#[test]
fn batcher_serves_cnv_through_nchw_adapter() {
    let batcher = Batcher::start(
        || Ok(Box::new(PlannedEngine::from_zoo("CNV-w2a2")?) as Box<dyn InferenceEngine>),
        BatcherConfig::default(),
    )
    .unwrap();
    let input: Vec<f32> = (0..3072).map(|i| (i % 11) as f32 / 11.0).collect();
    let served = batcher.infer(input.clone()).unwrap();
    assert_eq!(served.len(), 10);

    let mut g = zoo::build("CNV-w2a2", 1, 32).unwrap();
    transforms::cleanup(&mut g).unwrap();
    let x = Tensor::new(vec![1, 3, 32, 32], input);

    // byte-exact vs the streamlined graph through the interpreter
    let sl = qonnx::streamline::try_streamline(&g).unwrap();
    assert!(sl.report.ok, "{}", sl.report.render());
    let want = exec::execute_simple(&sl.graph, &x).unwrap();
    assert_eq!(served, want.as_f32().unwrap());

    // close to the original float graph at the scaled output edge
    let yf = exec::execute_simple(&g, &x).unwrap();
    for (a, b) in served.iter().zip(yf.as_f32().unwrap()) {
        assert!((a - b).abs() <= 1.0, "served {a} vs float {b}");
    }
}

/// The PR-4 acceptance case: streamlining lowers the zoo models end to
/// end, the quantized integer plan is byte-identical to the float
/// interpreter ON the streamlined graph (the 2^24 exactness contract),
/// and the streamlined outputs track the original float model within the
/// documented tolerance (exactness holds only where every scale is a
/// power of two; the zoo's 1/255 input scale admits rare
/// rounding-boundary level flips, each worth a few 0.0625-grid steps at
/// the output edge).
#[test]
fn streamlined_integer_plan_matches_interpreter_on_zoo() {
    for (name, min_quant) in [("TFC-w1a1", 4usize), ("TFC-w2a2", 4), ("CNV-w2a2", 9)] {
        let mut g = zoo::build(name, 1, 32).unwrap();
        transforms::cleanup(&mut g).unwrap();
        let sl = qonnx::streamline::try_streamline(&g).unwrap();
        assert!(sl.report.ok, "'{name}' must streamline:\n{}", sl.report.render());
        let sg = sl.graph;
        let h = sg.op_histogram();
        assert!(!h.contains_key("Quant"), "'{name}' kept Quant nodes: {h:?}");
        assert!(!h.contains_key("BipolarQuant"), "'{name}' kept BipolarQuant nodes: {h:?}");
        assert!(!h.contains_key("BatchNormalization"), "'{name}' kept BatchNorm: {h:?}");

        let plan = ExecutionPlan::compile(&sg).unwrap();
        assert!(
            plan.quant_kernel_count() >= min_quant,
            "'{name}' expected >= {min_quant} quantized kernels:\n{}",
            plan.summary()
        );

        let inputs = random_inputs(&sg, 41);
        // quantized plan == float interpreter on the streamlined graph,
        // byte for byte (integer math below 2^24 is exact in f32)
        let got = plan.run(&inputs).unwrap();
        let want = exec::interpret(&sg, &inputs).unwrap();
        assert_eq!(want.outputs, got, "'{name}': quantized plan diverged");

        // and the float plan on the streamlined graph agrees too
        let float_opts = PlanOptions { quantize: false, ..Default::default() };
        let fplan = ExecutionPlan::compile_with(&sg, &float_opts).unwrap();
        assert_eq!(fplan.quant_kernel_count(), 0);
        assert_eq!(fplan.run(&inputs).unwrap(), got, "'{name}': float/quant tier split");

        // original float model: documented tolerance at the output edge
        let orig = exec::interpret(&g, &inputs).unwrap();
        for (out_name, t) in &got {
            let a = t.as_f32().unwrap();
            let b = orig.outputs[out_name].as_f32().unwrap();
            for (x, y) in a.iter().zip(b) {
                assert!(
                    (x - y).abs() <= 1.0,
                    "'{name}' output '{out_name}': streamlined {x} vs float {y}"
                );
            }
        }
    }
}

/// The PR-5 acceptance case: in a streamlined plan, every intermediate
/// slot between the first and the last quantized kernel is an integer
/// slot (zero f32 intermediates — activations stay resident in `i8`/
/// `i32` containers), and residency changes *traffic only*: the resident
/// plan is byte-identical to the convert-per-call plan and the
/// interpreter, batched included.
#[test]
fn streamlined_plans_keep_integer_residency() {
    for name in ["TFC-w1a1", "TFC-w2a2", "CNV-w2a2"] {
        let mut g = zoo::build(name, 1, 32).unwrap();
        transforms::cleanup(&mut g).unwrap();
        let sl = qonnx::streamline::try_streamline(&g).unwrap();
        assert!(sl.report.ok, "{}", sl.report.render());
        let sg = sl.graph;
        let plan = ExecutionPlan::compile(&sg).unwrap();
        assert!(
            plan.resident_int_count() >= 2,
            "'{name}' expected integer-resident values:\n{}",
            plan.summary()
        );

        // the quantized-kernel span: every output slot of the first
        // quantized step up to (excluding) the last quantized step must
        // be an integer slot — the last kernel itself emits f32 for the
        // residual de-scale edge, which is outside the region
        let table = plan.step_table();
        let qsteps: Vec<usize> = table
            .iter()
            .enumerate()
            .filter(|(_, (tag, _))| tag.starts_with("Quant"))
            .map(|(i, _)| i)
            .collect();
        assert!(qsteps.len() >= 2, "'{name}':\n{}", plan.summary());
        let (first, last) = (qsteps[0], *qsteps.last().unwrap());
        let dtypes = plan.slot_dtypes();
        for (i, (tag, outs)) in table.iter().enumerate() {
            if i < first || i >= last {
                continue;
            }
            for slot in outs.iter().flatten() {
                assert_ne!(
                    dtypes[*slot as usize],
                    DType::F32,
                    "'{name}' step {i} ({tag}) allocated an f32 intermediate inside the \
                     quantized region:\n{}",
                    plan.summary()
                );
            }
        }

        // byte-identity: resident vs convert-per-call vs interpreter
        let inputs = random_inputs(&sg, 47);
        let got = plan.run(&inputs).unwrap();
        let convert_opts = PlanOptions { int_residency: false, ..Default::default() };
        let cplan = ExecutionPlan::compile_with(&sg, &convert_opts).unwrap();
        assert_eq!(cplan.resident_int_count(), 0);
        assert_eq!(cplan.run(&inputs).unwrap(), got, "'{name}': residency changed values");
        assert_eq!(exec::interpret(&sg, &inputs).unwrap().outputs, got);
    }
}

/// Back-to-back quantized layers hand activations over in a resident
/// `i8` container (the i8-activation GEMM path), byte-identical both to
/// the streamlined interpreter run and — all scales dyadic — to the
/// original float graph.
#[test]
fn back_to_back_quantized_layers_hand_off_resident_i8() {
    let mut b = GraphBuilder::new("i8handoff");
    b.input("x", vec![2, 12]);
    b.quant("x", "xq", 0.25, 0.0, 4.0, true, false, "ROUND");
    b.initializer(
        "w0",
        Tensor::new(vec![12, 10], (0..120).map(|v| ((v % 9) as f32 - 4.0) * 0.6).collect()),
    );
    b.quant("w0", "w0q", 0.5, 0.0, 3.0, true, true, "ROUND");
    b.node("MatMul", &["xq", "w0q"], &["h"], &[]);
    b.quant("h", "hq", 0.5, 0.0, 4.0, true, false, "ROUND");
    b.initializer(
        "w1",
        Tensor::new(vec![10, 4], (0..40).map(|v| ((v % 7) as f32 - 3.0) * 0.4).collect()),
    );
    b.quant("w1", "w1q", 0.5, 0.0, 3.0, true, true, "ROUND");
    b.node("MatMul", &["hq", "w1q"], &["y"], &[]);
    b.output("y", vec![2, 4]);
    let g = b.finish().unwrap();

    let sl = qonnx::streamline::try_streamline(&g).unwrap();
    assert!(sl.report.ok, "{}", sl.report.render());
    let plan = ExecutionPlan::compile(&sl.graph).unwrap();
    assert!(plan.quant_kernel_count() >= 2, "{}", plan.summary());
    // int4 levels fit i8: both the input MultiThreshold and the fused
    // inter-layer threshold emit into i8 slots
    assert!(
        plan.slot_dtypes().contains(&DType::I8),
        "expected a resident i8 handoff slot:\n{}",
        plan.summary()
    );
    let inputs = random_inputs(&sl.graph, 53);
    let got = plan.run(&inputs).unwrap();
    assert_eq!(exec::interpret(&sl.graph, &inputs).unwrap().outputs, got);
    // dyadic scales end to end: exact vs the original float graph too
    assert_eq!(exec::interpret(&g, &inputs).unwrap().outputs, got);
}

/// Batched streamlined CNV: one quantized-plan invocation on a batch-4
/// request equals four per-sample runs byte-for-byte (the batch-symbolic
/// reshape rewrite and the quantized kernels compose).
#[test]
fn streamlined_cnv_batches_natively() {
    let mut g = zoo::build("CNV-w2a2", 1, 32).unwrap();
    transforms::cleanup(&mut g).unwrap();
    let sl = qonnx::streamline::try_streamline(&g).unwrap();
    assert!(sl.report.ok, "{}", sl.report.render());
    let plan = ExecutionPlan::compile(&sl.graph).unwrap();
    assert!(plan.batch_symbolic_count() >= 1, "{}", plan.summary());
    assert!(plan.batch_blockers().is_empty(), "{}", plan.summary());

    let in_name = sl.graph.inputs[0].name.clone();
    let out_name = sl.graph.outputs[0].name.clone();
    let n = 4usize;
    let mut rng = Rng::new(53);
    let xb = random_tensor(&mut rng, vec![n, 3, 32, 32], 0.0, 1.0);
    let cfg = RunConfig { shape_check: ShapeCheck::FreeBatch, record_intermediates: false };
    let yb = plan
        .run_cfg(|nm| (nm == in_name).then_some(&xb), &cfg)
        .unwrap()
        .outputs
        .remove(&out_name)
        .unwrap();
    assert_eq!(yb.shape(), &[n, 10]);
    let rows = xb.as_f32().unwrap();
    for r in 0..n {
        let img = Tensor::new(vec![1, 3, 32, 32], rows[r * 3072..(r + 1) * 3072].to_vec());
        let mut m = BTreeMap::new();
        m.insert(in_name.clone(), img);
        let y1 = plan.run(&m).unwrap().remove(&out_name).unwrap();
        assert_eq!(&yb.as_f32().unwrap()[r * 10..(r + 1) * 10], y1.as_f32().unwrap(), "row {r}");
    }
}

/// The tentpole acceptance case: one batch-symbolic plan executes a
/// batch-8 CNV request in ONE invocation, byte-identical both to eight
/// per-sample plan runs and to eight interpreter runs.
#[test]
fn cnv_batched_plan_matches_per_sample_and_interpreter() {
    let mut g = zoo::build("CNV-w2a2", 1, 32).unwrap();
    transforms::cleanup(&mut g).unwrap();
    let plan = ExecutionPlan::compile(&g).unwrap();
    assert!(
        plan.batch_symbolic_count() >= 1,
        "CNV's baked flatten target must be rewritten:\n{}",
        plan.summary()
    );
    let in_name = g.inputs[0].name.clone();
    let out_name = g.outputs[0].name.clone();
    let n = 8usize;
    let mut rng = Rng::new(77);
    let xb = random_tensor(&mut rng, vec![n, 3, 32, 32], 0.0, 1.0);

    // one invocation for the whole batch (leading axis free)
    let cfg = RunConfig { shape_check: ShapeCheck::FreeBatch, record_intermediates: false };
    let yb = plan
        .run_cfg(|nm| (nm == in_name).then_some(&xb), &cfg)
        .unwrap()
        .outputs
        .remove(&out_name)
        .unwrap();
    assert_eq!(yb.shape(), &[n, 10]);

    let rows = xb.as_f32().unwrap();
    let yrows = yb.as_f32().unwrap();
    for r in 0..n {
        let img = Tensor::new(vec![1, 3, 32, 32], rows[r * 3072..(r + 1) * 3072].to_vec());
        let mut m = BTreeMap::new();
        m.insert(in_name.clone(), img);
        // per-sample plan run (exact declared shapes)
        let y1 = plan.run(&m).unwrap().remove(&out_name).unwrap();
        assert_eq!(&yrows[r * 10..(r + 1) * 10], y1.as_f32().unwrap(), "plan row {r}");
        // name-keyed interpreter
        let yi = exec::interpret(&g, &m).unwrap().outputs.remove(&out_name).unwrap();
        assert_eq!(&yrows[r * 10..(r + 1) * 10], yi.as_f32().unwrap(), "interp row {r}");
    }
}

/// Two sharded batcher workers serve the SAME `Arc`'d compiled plan —
/// sharding duplicates no packed weights — and agree with direct
/// execution.
#[test]
fn sharded_batcher_workers_share_one_arc_plan() {
    let template = PlannedEngine::from_zoo("CNV-w2a2").unwrap();
    let plan = template.plan_handle();
    // template + our handle
    assert_eq!(Arc::strong_count(&plan), 2);
    let batcher = Batcher::start_sharded(
        move || Ok(Box::new(template.share()) as Box<dyn InferenceEngine>),
        BatcherConfig::default(),
        2,
    )
    .unwrap();
    // both worker engines came up (start_sharded waits for readiness)
    // holding Arc views of the one plan: template-in-factory + 2 workers
    assert_eq!(Arc::strong_count(&plan), 4);

    let input: Vec<f32> = (0..3072).map(|i| (i % 23) as f32 / 23.0).collect();
    let served = batcher.infer(input.clone()).unwrap();
    let mut direct = PlannedEngine::from_zoo("CNV-w2a2").unwrap();
    let want = direct.infer_batch(&Tensor::new(vec![1, 3072], input)).unwrap();
    assert_eq!(served, want.as_f32().unwrap());

    // shutdown drops the worker engines and the factory's template
    batcher.shutdown();
    assert_eq!(Arc::strong_count(&plan), 1);
}

/// The PR-6 acceptance case: quantized plans are byte-identical across
/// microkernel substrates. `QONNX_FORCE_SCALAR=1` is honored two ways —
/// a plan compiled under it packs no SIMD tiles at all, and a plan
/// compiled with tiles flips back to the scalar panels at run time — and
/// both match the detected-best run bit for bit (i32 accumulation is
/// order-free, so the ISA cannot leak into values).
#[test]
fn forced_scalar_plans_are_byte_identical_to_simd() {
    for name in ["TFC-w2a2", "CNV-w2a2"] {
        let mut g = zoo::build(name, 1, 32).unwrap();
        transforms::cleanup(&mut g).unwrap();
        let sl = qonnx::streamline::try_streamline(&g).unwrap();
        assert!(sl.report.ok, "{}", sl.report.render());
        let sg = sl.graph;
        let inputs = random_inputs(&sg, 61);

        // detected-best substrate (scalar on hosts without AVX2/NEON)
        let best = ExecutionPlan::compile(&sg).unwrap();
        assert!(best.summary().contains("kernel substrate"), "{}", best.summary());
        let want = best.run(&inputs).unwrap();

        std::env::set_var("QONNX_FORCE_SCALAR", "1");
        // freshly compiled: packs scalar panels only
        let scalar = ExecutionPlan::compile(&sg).unwrap();
        assert!(
            scalar.summary().contains("forced scalar")
                && scalar.summary().contains("0/"),
            "{}",
            scalar.summary()
        );
        let got_scalar = scalar.run(&inputs).unwrap();
        // already-compiled (possibly SIMD-tiled): flips at run time
        let got_flipped = best.run(&inputs).unwrap();
        std::env::remove_var("QONNX_FORCE_SCALAR");

        assert_eq!(want, got_scalar, "'{name}': scalar-packed plan diverged");
        assert_eq!(want, got_flipped, "'{name}': runtime scalar flip diverged");
    }
}

/// One compiled plan serves every batch size: replicated rows give
/// replicated (bit-identical) outputs.
#[test]
fn planned_engine_rebatches_without_recompiling() {
    let mut engine = PlannedEngine::from_zoo("TFC-w2a2").unwrap();
    let row: Vec<f32> = (0..784).map(|i| (i % 17) as f32 / 17.0).collect();
    let y1 = engine.infer_batch(&Tensor::new(vec![1, 784], row.clone())).unwrap();
    let mut four = Vec::new();
    for _ in 0..4 {
        four.extend_from_slice(&row);
    }
    let y4 = engine.infer_batch(&Tensor::new(vec![4, 784], four)).unwrap();
    assert_eq!(y4.shape(), &[4, 10]);
    for r in 0..4 {
        assert_eq!(&y4.as_f32().unwrap()[r * 10..(r + 1) * 10], y1.as_f32().unwrap());
    }
}

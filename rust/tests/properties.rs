//! Property-based invariants of the quantization operators and passes
//! (lightweight proptest substitute: seeded random sweeps with
//! reproduction seeds on failure).

use qonnx::ir::Node;
use qonnx::ops::quant::{quant_bounds, quant_op, round_half_even, RoundingMode};
use qonnx::tensor::Tensor;
use qonnx::testutil::{for_all_seeds, random_tensor};

fn quant(x: &Tensor, s: f32, z: f32, bw: f32, signed: bool, narrow: bool, mode: &str) -> Tensor {
    let n = Node::new("Quant", &["x", "s", "z", "b"], &["y"])
        .with_attr("signed", signed)
        .with_attr("narrow", narrow)
        .with_attr("rounding_mode", mode);
    quant_op(&n, &[x, &Tensor::scalar(s), &Tensor::scalar(z), &Tensor::scalar(bw)]).unwrap().remove(0)
}

/// quantize(quantize(x)) == quantize(x): idempotence.
#[test]
fn prop_quant_idempotent() {
    for_all_seeds(25, |rng| {
        let bw = [2.0f32, 3.0, 4.0, 6.0, 8.0][rng.below(5)];
        let s = [0.05f32, 0.125, 0.5, 1.0, 3.0][rng.below(5)];
        let signed = rng.below(2) == 0;
        let narrow = rng.below(2) == 0;
        let x = random_tensor(rng, vec![3, 17], -20.0, 20.0);
        let y1 = quant(&x, s, 0.0, bw, signed, narrow, "ROUND");
        let y2 = quant(&y1, s, 0.0, bw, signed, narrow, "ROUND");
        assert_eq!(y1, y2, "bw={bw} s={s} signed={signed} narrow={narrow}");
    });
}

/// Quantized outputs land on the scale grid within the Eq. 2-3 bounds.
#[test]
fn prop_quant_output_on_grid_within_bounds() {
    for_all_seeds(25, |rng| {
        let bw = 2.0 + rng.below(7) as f32;
        let s = 0.05 + rng.uniform();
        let z = rng.below(3) as f32;
        let signed = rng.below(2) == 0;
        let x = random_tensor(rng, vec![64], -50.0, 50.0);
        let y = quant(&x, s, z, bw, signed, false, "ROUND");
        let (lo, hi) = quant_bounds(signed, false, f64::from(bw));
        for &v in y.as_f32().unwrap() {
            let q = f64::from(v) / f64::from(s) + f64::from(z);
            assert!(q.round() - q < 1e-3, "off grid: q={q}");
            assert!(q >= lo - 1e-3 && q <= hi + 1e-3, "out of bounds: q={q} in [{lo},{hi}]");
        }
    });
}

/// Quantization is monotone: x1 <= x2 implies Q(x1) <= Q(x2).
#[test]
fn prop_quant_monotone() {
    for_all_seeds(25, |rng| {
        let bw = 2.0 + rng.below(7) as f32;
        let s = 0.1 + rng.uniform();
        let mut vals: Vec<f32> = (0..32).map(|_| rng.range(-10.0, 10.0)).collect();
        vals.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let x = Tensor::new(vec![32], vals);
        let y = quant(&x, s, 0.0, bw, true, false, "ROUND");
        let out = y.as_f32().unwrap();
        for w in out.windows(2) {
            assert!(w[0] <= w[1], "not monotone: {:?}", out);
        }
    });
}

/// Quantization error is bounded by s/2 inside the clip range.
#[test]
fn prop_quant_error_bounded() {
    for_all_seeds(25, |rng| {
        let s = 0.1 + rng.uniform() * 0.5;
        let x = random_tensor(rng, vec![64], -3.0, 3.0);
        let y = quant(&x, s, 0.0, 8.0, true, false, "ROUND");
        for (a, b) in x.as_f32().unwrap().iter().zip(y.as_f32().unwrap()) {
            assert!((a - b).abs() <= s / 2.0 + 1e-5, "err {} > s/2 {}", (a - b).abs(), s / 2.0);
        }
    });
}

/// All four rounding modes agree off tie points and differ as documented
/// on exact .5 points.
#[test]
fn prop_rounding_mode_relations() {
    for_all_seeds(25, |rng| {
        let v = f64::from(rng.range(-100.0, 100.0));
        let r = round_half_even(v);
        assert!(RoundingMode::Floor.apply(v) <= r + 1e-9);
        assert!(RoundingMode::Ceil.apply(v) >= r - 1e-9);
        assert!(RoundingMode::Ceil.apply(v) - RoundingMode::Floor.apply(v) <= 1.0);
        assert!(RoundingMode::RoundToZero.apply(v).abs() <= v.abs());
    });
}

/// Narrow range loses exactly one level on the appropriate side.
#[test]
fn prop_narrow_range_one_level() {
    for bw in 2..=8 {
        let bw = f64::from(bw);
        let (lo, hi) = quant_bounds(true, false, bw);
        let (nlo, nhi) = quant_bounds(true, true, bw);
        assert_eq!(nlo, lo + 1.0);
        assert_eq!(nhi, hi);
        let (ulo, uhi) = quant_bounds(false, false, bw);
        let (unlo, unhi) = quant_bounds(false, true, bw);
        assert_eq!(unlo, ulo);
        assert_eq!(unhi, uhi - 1.0);
    }
}

/// Cleanup never changes observable behavior on random DAGs of supported
/// ops (a mini graph-fuzzer).
#[test]
fn prop_cleanup_preserves_random_graphs() {
    use qonnx::ir::GraphBuilder;
    for_all_seeds(15, |rng| {
        let mut b = GraphBuilder::new("fuzz");
        b.input("x", vec![2, 8]);
        let mut cur = "x".to_string();
        let depth = 2 + rng.below(4);
        for i in 0..depth {
            let next = format!("t{i}");
            match rng.below(5) {
                0 => {
                    b.node("Relu", &[&cur], &[&next], &[]);
                }
                1 => {
                    let c = format!("c{i}");
                    b.scalar(&c, rng.range(0.5, 2.0));
                    b.node("Mul", &[&cur, &c], &[&next], &[]);
                }
                2 => {
                    b.quant(&cur, &next, 0.25, 0.0, 4.0, true, false, "ROUND");
                }
                3 => {
                    b.node("Identity", &[&cur], &[&next], &[]);
                }
                _ => {
                    let c = format!("c{i}");
                    b.scalar(&c, rng.range(-1.0, 1.0));
                    b.node("Add", &[&cur, &c], &[&next], &[]);
                }
            }
            cur = next;
        }
        b.node("Identity", &[&cur], &["y"], &[]);
        b.output("y", vec![2, 8]);
        let g0 = b.finish().unwrap();
        let mut g1 = g0.clone();
        qonnx::transforms::cleanup(&mut g1).unwrap();
        let x = random_tensor(rng, vec![2, 8], -4.0, 4.0);
        assert_eq!(
            qonnx::exec::execute_simple(&g0, &x).unwrap(),
            qonnx::exec::execute_simple(&g1, &x).unwrap()
        );
    });
}

/// MultiThreshold conversion equals direct Quant on integer-grid inputs
/// for random parameters (the FINN-equivalence property).
#[test]
fn prop_multithreshold_equals_quant_on_grid() {
    use qonnx::transforms::quant_to_thresholds;
    for_all_seeds(25, |rng| {
        let bw = 2.0 + rng.below(5) as f64;
        let signed = rng.below(2) == 0;
        let s = [0.25f64, 0.5, 1.0, 2.0][rng.below(4)];
        let (th, os, ob) = quant_to_thresholds(&[s], 0.0, bw, signed, false, "ROUND").unwrap();
        let node = Node::new("MultiThreshold", &["x", "t"], &["y"])
            .with_attr("out_scale", os)
            .with_attr("out_bias", ob);
        // integer-grid inputs (accumulator-like): x = s * k for integer k
        let ks: Vec<f32> = (0..32).map(|_| (rng.below(41) as f32 - 20.0)).collect();
        let x = Tensor::new(vec![1, 32], ks.iter().map(|k| k * s as f32).collect());
        let y_mt = qonnx::ops::multithreshold::multi_threshold(&node, &[&x, &th]).unwrap().remove(0);
        let y_q = quant(&x, s as f32, 0.0, bw as f32, signed, false, "ROUND");
        assert_eq!(y_mt, y_q, "bw={bw} signed={signed} s={s}");
    });
}

/// Integer-residency container property: across random bit widths, zero
/// points, and signedness, a streamlined `MultiThreshold`'s emitted
/// levels always fit the container the residency pass declares — `i8`
/// exactly when the level range `[qmin - z, qmax - z]` fits, `i32`
/// otherwise — and the resident plan stays byte-identical to the
/// interpreter (an overflowing container would wrap and diverge).
#[test]
fn prop_mt_levels_fit_declared_container() {
    use qonnx::plan::ExecutionPlan;
    use qonnx::tensor::DType;
    for_all_seeds(20, |rng| {
        let signed = rng.below(2) == 0;
        let bw = if signed { 2.0 + rng.below(7) as f32 } else { 1.0 + rng.below(8) as f32 };
        let z = if signed { 0.0 } else { rng.below(3) as f32 };
        let s = [0.125f32, 0.25, 0.5, 1.0][rng.below(4)];
        let mut b = qonnx::ir::GraphBuilder::new("mtfit");
        b.input("x", vec![2, 9]);
        b.quant("x", "xq", s, z, bw, signed, false, "ROUND");
        b.initializer(
            "w",
            random_tensor(rng, vec![9, 4], -1.5, 1.5),
        );
        b.quant("w", "wq", 1.0, 0.0, 3.0, true, false, "ROUND");
        b.node("MatMul", &["xq", "wq"], &["y"], &[]);
        b.output("y", vec![2, 4]);
        let g = b.finish().unwrap();
        let att = qonnx::streamline::try_streamline(&g).unwrap();
        assert!(att.report.ok, "{}", att.report.render());
        let plan = ExecutionPlan::compile(&att.graph).unwrap();

        // the declared container of the input MultiThreshold must cover
        // its level range exactly
        let (qmin, qmax) = quant_bounds(signed, false, f64::from(bw));
        let (lo, hi) = (qmin - f64::from(z), qmax - f64::from(z));
        let want = if lo >= -128.0 && hi <= 127.0 { DType::I8 } else { DType::I32 };
        let table = plan.step_table();
        let mt_tag = &table
            .iter()
            .find(|(tag, _)| tag.starts_with("Threshold"))
            .unwrap_or_else(|| panic!("no Threshold step:\n{}", plan.summary()))
            .0;
        assert_eq!(
            mt_tag,
            &format!("Threshold({want})"),
            "bw={bw} z={z} signed={signed}:\n{}",
            plan.summary()
        );

        // byte-identity proves every emitted level fit its container
        let mut inputs = std::collections::BTreeMap::new();
        inputs.insert("x".to_string(), random_tensor(rng, vec![2, 9], -6.0, 6.0));
        let got = plan.run(&inputs).unwrap();
        let want_out = qonnx::exec::interpret(&att.graph, &inputs).unwrap();
        assert_eq!(want_out.outputs, got, "bw={bw} z={z} s={s} signed={signed}");
    });
}

/// Streamlining a random `Quant` activation into the integer-domain
/// `MultiThreshold` form (thresholds computed in the producer's integer
/// domain, raw levels emitted, scale pushed to the graph edge) is
/// bit-exact on dyadic grids — **including half-grid tie points**, where
/// round-half-even and the threshold nudges must agree.
#[test]
fn prop_streamlined_quant_matches_quant_op_with_ties() {
    for_all_seeds(25, |rng| {
        let bw = 2.0 + rng.below(5) as f32;
        let s = [0.25f32, 0.5, 1.0, 2.0][rng.below(4)];
        let s_in = [0.25f32, 0.5, 1.0][rng.below(3)];
        let signed = rng.below(2) == 0;
        let narrow = rng.below(2) == 0;
        let mut b = qonnx::ir::GraphBuilder::new("pq");
        b.input("x", vec![1, 64]);
        b.quant("x", "xq", s_in, 0.0, 8.0, true, false, "ROUND");
        b.quant("xq", "y", s, 0.0, bw, signed, narrow, "ROUND");
        b.output("y", vec![1, 64]);
        let g = b.finish().unwrap();
        let att = qonnx::streamline::try_streamline(&g).unwrap();
        assert!(att.report.ok, "{}", att.report.render());
        // inputs on the s_in grid and its half-grid tie points
        let vals: Vec<f32> = (0..64).map(|i| (i as f32 - 32.0) * 0.5 * s_in).collect();
        let x = Tensor::new(vec![1, 64], vals);
        assert_eq!(
            qonnx::exec::execute_simple(&g, &x).unwrap(),
            qonnx::exec::execute_simple(&att.graph, &x).unwrap(),
            "bw={bw} s={s} s_in={s_in} signed={signed} narrow={narrow}"
        );
    });
}

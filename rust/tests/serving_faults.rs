//! Fault-injection integration tests for the serving core: bounded
//! admission, request deadlines, shard supervision/restart, degraded
//! modes, and shutdown semantics, all driven through [`FaultyEngine`]
//! wrapping a real compiled [`PlannedEngine`] (TFC-w2a2).
//!
//! Every test asserts the core robustness contract: an admitted request
//! gets a *definitive typed outcome* — never a hung recv.

use qonnx::coordinator::{
    Batcher, BatcherConfig, DegradedPolicy, FaultAction, FaultInjector, FaultyEngine,
    InferenceEngine, PlannedEngine, ServeError, SubmitError, SubmitOptions, SupervisorConfig,
};
use qonnx::metrics::serving::BatchCloseReason;
use qonnx::tensor::Tensor;
use qonnx::trace::{EventKind, TraceRecorder};
use qonnx::zoo::{tfc_batch, TfcParams};
use std::sync::Arc;
use std::time::{Duration, Instant};

const IN: usize = 784;
const OUT: usize = 10;

fn tfc_engine() -> PlannedEngine {
    let g = tfc_batch(&TfcParams::random(2, 2, 5), 1).unwrap();
    PlannedEngine::new(&g).unwrap()
}

/// Factory producing fault-wrapped shared views of one compiled plan.
fn faulty_factory(
    template: &PlannedEngine,
    inj: &FaultInjector,
) -> impl Fn() -> anyhow::Result<Box<dyn InferenceEngine>> + Send + Sync + 'static {
    let t = template.share();
    let inj = inj.clone();
    move || {
        Ok(Box::new(FaultyEngine::new(Box::new(t.share()), inj.clone()))
            as Box<dyn InferenceEngine>)
    }
}

/// Supervisor tuned for test speed: tight tick, near-instant restarts.
fn fast_supervisor() -> SupervisorConfig {
    SupervisorConfig {
        tick: Duration::from_millis(1),
        restart_backoff: Duration::from_millis(1),
        max_backoff: Duration::from_millis(20),
        ..Default::default()
    }
}

fn wait_until(timeout: Duration, mut f: impl FnMut() -> bool) -> bool {
    let start = Instant::now();
    while start.elapsed() < timeout {
        if f() {
            return true;
        }
        std::thread::sleep(Duration::from_millis(2));
    }
    f()
}

#[test]
fn overload_sheds_typed_and_depth_stays_bounded() {
    let template = tfc_engine();
    let inj = FaultInjector::new();
    inj.set_default(FaultAction::Stall(Duration::from_millis(10)));
    let cfg = BatcherConfig {
        // close batches instantly: the worker is stalling in infer_batch
        // (not gathering) while the submit loop runs, so the queue
        // deterministically fills to the cap and sheds
        max_wait: Duration::ZERO,
        queue_capacity: Some(4),
        supervisor: fast_supervisor(),
        ..Default::default()
    };
    let b = Batcher::start_sharded(faulty_factory(&template, &inj), cfg, 1).unwrap();

    let mut accepted = Vec::new();
    let mut shed = 0u64;
    for _ in 0..64 {
        match b.submit(vec![0.25; IN]) {
            Ok(r) => accepted.push(r),
            Err(SubmitError::Shed { queue_depth }) => {
                assert_eq!(queue_depth, 4, "shed must report the full queue");
                shed += 1;
            }
            Err(e) => panic!("unexpected submit error: {e}"),
        }
    }
    assert!(shed > 0, "64 instant submits against a stalled engine must shed");
    assert_eq!(b.metrics().shed(), shed);
    assert!(b.metrics().queue_depth_peak() <= 4, "queue depth must never exceed the cap");

    // every *accepted* request still resolves definitively
    for r in accepted {
        assert_eq!(r.wait().unwrap().len(), OUT);
    }

    // a caller willing to wait for space gets admitted instead of shed
    let mut pending = Vec::new();
    loop {
        match b.submit(vec![0.5; IN]) {
            Ok(r) => pending.push(r),
            Err(SubmitError::Shed { .. }) => break,
            Err(e) => panic!("unexpected submit error: {e}"),
        }
    }
    let opts = SubmitOptions { deadline: None, submit_timeout: Some(Duration::from_secs(10)) };
    let waited = b.submit_with(vec![0.5; IN], opts).expect("submit_timeout caller is admitted");
    for r in pending {
        assert_eq!(r.wait().unwrap().len(), OUT);
    }
    assert_eq!(waited.wait().unwrap().len(), OUT);

    // observability contract: every executed batch lands in the
    // batch-size histogram and in exactly one close-reason counter
    let m = b.metrics();
    let batches = m.batches();
    assert!(batches > 0, "the stalled engine still executed batches");
    assert_eq!(m.batch_size().count(), batches, "one histogram sample per batch");
    assert!(m.batch_size().sum_us() >= batches, "batches hold >= 1 request each");
    let by_reason: u64 = BatchCloseReason::ALL.iter().map(|r| m.batch_closes(*r)).sum();
    assert_eq!(by_reason, batches, "close reasons partition the batch count");
    // and the per-model exposition carries the stable kebab-case label
    let text = m.render_text_for(Some("TFC-w2a2"));
    assert!(text.contains("qonnx_batch_size_count{model=\"tfc-w2a2\"}"));
    assert!(text.contains("qonnx_batches_closed_total{model=\"tfc-w2a2\",reason=\"full\"}"));
}

#[test]
fn shard_restarts_after_panic_and_serves_identically() {
    let template = tfc_engine();
    let inj = FaultInjector::new();
    let cfg = BatcherConfig { supervisor: fast_supervisor(), ..Default::default() };
    let b = Arc::new(Batcher::start_sharded(faulty_factory(&template, &inj), cfg, 1).unwrap());

    assert_eq!(b.infer(vec![0.1; IN]).unwrap().len(), OUT);

    inj.arm(FaultAction::Panic);
    let err = b.submit(vec![0.2; IN]).unwrap().wait().unwrap_err();
    assert!(matches!(err, ServeError::ShardPanicked { .. }), "want ShardPanicked, got {err:?}");

    assert!(
        wait_until(Duration::from_secs(5), || {
            let h = b.health();
            h.live == 1 && h.restarts >= 1
        }),
        "shard must restart to full health, got {:?}",
        b.health()
    );
    assert_eq!(b.metrics().shard_panics(), 1);
    assert!(b.metrics().shard_restarts() >= 1);

    // after recovery, concurrent requests match the direct engine
    // byte-for-byte
    let mut handles = Vec::new();
    for i in 0..8usize {
        let b = b.clone();
        handles.push(std::thread::spawn(move || {
            let input: Vec<f32> =
                (0..IN).map(|j| ((i * 97 + j) % 11) as f32 / 11.0).collect();
            let out = b.infer(input.clone()).unwrap();
            (input, out)
        }));
    }
    let mut direct = template.share();
    for h in handles {
        let (input, got) = h.join().unwrap();
        let want = direct.infer_batch(&Tensor::new(vec![1, IN], input)).unwrap();
        assert_eq!(got, want.as_f32().unwrap(), "post-restart output must be byte-identical");
    }
}

#[test]
fn trace_spans_stay_balanced_under_shard_panics() {
    let template = tfc_engine();
    let inj = FaultInjector::new();
    let rec = Arc::new(TraceRecorder::new(8192));
    let cfg = BatcherConfig {
        supervisor: fast_supervisor(),
        trace: Some(rec.clone()),
        ..Default::default()
    };
    let b = Batcher::start_sharded(faulty_factory(&template, &inj), cfg, 1).unwrap();

    // healthy traffic, a panic mid-batch, a restart, healthy traffic again
    assert_eq!(b.infer(vec![0.1; IN]).unwrap().len(), OUT);
    inj.arm(FaultAction::Panic);
    let err = b.submit(vec![0.2; IN]).unwrap().wait().unwrap_err();
    assert!(matches!(err, ServeError::ShardPanicked { .. }), "got {err:?}");
    assert!(
        wait_until(Duration::from_secs(5), || {
            let h = b.health();
            h.live == 1 && h.restarts >= 1
        }),
        "shard must restart, got {:?}",
        b.health()
    );
    assert_eq!(b.infer(vec![0.3; IN]).unwrap().len(), OUT);
    b.shutdown();

    let tracks = rec.drain();
    assert!(!tracks.is_empty(), "worker threads must have registered trace tracks");
    let mut saw = std::collections::BTreeSet::new();
    for t in &tracks {
        assert_eq!(t.dropped, 0, "an 8192-event ring must not drop under this load");
        // SpanEnd comes from a Drop guard, so even the batch a panic
        // unwound through must close its spans on that worker's track
        let mut depth = 0i64;
        for e in &t.events {
            match e.kind {
                EventKind::SpanBegin => depth += 1,
                EventKind::SpanEnd => {
                    depth -= 1;
                    assert!(depth >= 0, "SpanEnd before Begin on {:?}", t.thread_name);
                }
                _ => {}
            }
            if let Some(prefix) = e.name.split(':').next() {
                saw.insert(prefix.to_string());
            }
        }
        assert_eq!(depth, 0, "unbalanced spans on {:?} despite the panic", t.thread_name);
    }
    for want in ["admit", "queued", "batch", "execute", "shard-panic", "shard-restart"] {
        assert!(saw.contains(want), "lifecycle event '{want}' missing from {saw:?}");
    }
}

#[test]
fn deadline_exceeded_is_typed_and_bounded() {
    let template = tfc_engine();
    let inj = FaultInjector::new();
    inj.set_default(FaultAction::Stall(Duration::from_millis(300)));
    let cfg = BatcherConfig { supervisor: fast_supervisor(), ..Default::default() };
    let b = Batcher::start_sharded(faulty_factory(&template, &inj), cfg, 1).unwrap();

    // occupy the single shard so deadline-bearing requests wait behind it
    let busy = b.submit(vec![0.3; IN]).unwrap();
    std::thread::sleep(Duration::from_millis(30));

    // client-side: wait() returns within the deadline even though the
    // engine stalls far past it
    let start = Instant::now();
    let opts = SubmitOptions { deadline: Some(Duration::from_millis(40)), submit_timeout: None };
    let err = b.submit_with(vec![0.3; IN], opts).unwrap().wait().unwrap_err();
    assert!(matches!(err, ServeError::DeadlineExceeded { .. }), "got {err:?}");
    assert!(
        start.elapsed() < Duration::from_millis(250),
        "wait() must be bounded by the deadline, took {:?}",
        start.elapsed()
    );

    // server-side: the sweep delivers DeadlineExceeded with a positive
    // missed_by, observable on the raw receiver
    let opts = SubmitOptions { deadline: Some(Duration::from_millis(20)), submit_timeout: None };
    let rx = b.submit_with(vec![0.3; IN], opts).unwrap().into_receiver();
    match rx.recv_timeout(Duration::from_secs(5)).unwrap() {
        Err(ServeError::DeadlineExceeded { missed_by }) => {
            assert!(missed_by > Duration::ZERO, "server-side delivery reports lateness")
        }
        other => panic!("want server-side DeadlineExceeded, got {other:?}"),
    }
    let m = b.metrics();
    assert!(
        wait_until(Duration::from_secs(5), || m.deadline_exceeded() >= 2),
        "both expired requests must be counted, got {}",
        m.deadline_exceeded()
    );

    // the no-deadline request is untouched by its neighbors' expiry
    assert_eq!(busy.wait().unwrap().len(), OUT);
}

#[test]
fn one_panicking_shard_never_wedges_survivors() {
    let template = tfc_engine();
    let inj = FaultInjector::new();
    // slow restarts: the dead shard stays down while the survivor serves
    let sup = SupervisorConfig {
        tick: Duration::from_millis(1),
        restart_backoff: Duration::from_secs(2),
        max_backoff: Duration::from_secs(2),
        ..Default::default()
    };
    let cfg = BatcherConfig { supervisor: sup, ..Default::default() };
    let b = Batcher::start_sharded(faulty_factory(&template, &inj), cfg, 2).unwrap();

    inj.arm(FaultAction::Panic);
    let err = b.submit(vec![0.4; IN]).unwrap().wait().unwrap_err();
    assert!(matches!(err, ServeError::ShardPanicked { .. }), "got {err:?}");

    // the shared queue survived the panic: the other shard keeps serving
    for i in 0..16 {
        let y = b.infer(vec![i as f32 / 16.0; IN]).unwrap();
        assert_eq!(y.len(), OUT);
    }
    let h = b.health();
    assert_eq!(h.shards, 2);
    assert!(h.live >= 1, "survivor must stay live, got {h:?}");
    assert_eq!(b.metrics().shard_panics(), 1);
}

#[test]
fn refuse_when_degraded_policy_sheds_at_admission() {
    let template = tfc_engine();
    let inj = FaultInjector::new();
    let sup = SupervisorConfig {
        tick: Duration::from_millis(1),
        max_restarts: 0, // dead stays dead => degraded is observable
        degraded: DegradedPolicy::RefuseWhenDegraded,
        ..Default::default()
    };
    let cfg = BatcherConfig { supervisor: sup, ..Default::default() };
    let b = Batcher::start_sharded(faulty_factory(&template, &inj), cfg, 2).unwrap();

    inj.arm(FaultAction::Panic);
    let err = b.submit(vec![0.4; IN]).unwrap().wait().unwrap_err();
    assert!(matches!(err, ServeError::ShardPanicked { .. }), "got {err:?}");
    assert!(wait_until(Duration::from_secs(5), || b.health().dead == 1));

    match b.submit(vec![0.4; IN]) {
        Err(SubmitError::Degraded { live: 1, shards: 2 }) => {}
        other => panic!("refuse-when-degraded must shed typed, got {other:?}"),
    }
}

#[test]
fn all_shards_dead_is_typed_not_hung() {
    let template = tfc_engine();
    let inj = FaultInjector::new();
    inj.set_default(FaultAction::Panic);
    let sup = SupervisorConfig {
        tick: Duration::from_millis(1),
        max_restarts: 0,
        ..Default::default()
    };
    let cfg = BatcherConfig { supervisor: sup, ..Default::default() };
    let b = Batcher::start_sharded(faulty_factory(&template, &inj), cfg, 1).unwrap();

    let err = b.submit(vec![0.6; IN]).unwrap().wait().unwrap_err();
    assert!(matches!(err, ServeError::ShardPanicked { .. }), "got {err:?}");
    assert!(
        wait_until(Duration::from_secs(5), || b.health().all_dead()),
        "shard with no restart budget must go permanently dead"
    );

    match b.submit(vec![0.6; IN]) {
        Err(SubmitError::NoLiveShards) => {}
        other => panic!("submit against a dead server must fail typed, got {other:?}"),
    }
    let stats = b.shutdown();
    assert!(stats.requests >= 1);
}

#[test]
fn shutdown_gives_queued_requests_definitive_responses() {
    let template = tfc_engine();
    let inj = FaultInjector::new();
    inj.set_default(FaultAction::Stall(Duration::from_millis(30)));
    let cfg = BatcherConfig { supervisor: fast_supervisor(), ..Default::default() };
    let b = Batcher::start_sharded(faulty_factory(&template, &inj), cfg, 1).unwrap();

    let responses: Vec<_> =
        (0..8).map(|_| b.submit(vec![0.7; IN]).unwrap()).collect();
    b.shutdown();
    for r in responses {
        // drained => Ok rows; undrained => typed ShutDown. Never a hang.
        match r.wait() {
            Ok(rows) => assert_eq!(rows.len(), OUT),
            Err(ServeError::ShutDown) => {}
            Err(e) => panic!("unexpected shutdown-era error: {e:?}"),
        }
    }
}

#[test]
fn env_hook_injectors_are_deterministic() {
    // env mutation is process-wide; this is the only test touching these
    // vars, and it restores them before returning
    std::env::set_var("QONNX_FAULT_SEED", "7");
    std::env::set_var("QONNX_FAULT_RATE", "0.25");
    std::env::set_var("QONNX_FAULT_KIND", "error");
    let a = FaultInjector::from_env().expect("seed set => injection on");
    let b = FaultInjector::from_env().expect("seed set => injection on");
    std::env::remove_var("QONNX_FAULT_SEED");
    std::env::remove_var("QONNX_FAULT_RATE");
    std::env::remove_var("QONNX_FAULT_KIND");

    let sa: Vec<FaultAction> = (0..32).map(|_| a.next_action()).collect();
    let sb: Vec<FaultAction> = (0..32).map(|_| b.next_action()).collect();
    assert_eq!(sa, sb, "same (seed, rate, kind) must give the same schedule");
    assert!(sa.contains(&FaultAction::Error), "rate 0.25 over 32 draws must inject");
    assert!(sa.contains(&FaultAction::Serve));
    assert!(FaultInjector::from_env().is_none(), "no seed => injection off");
}

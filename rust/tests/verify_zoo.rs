//! Negative-result suite for the static plan verifier: every model-zoo
//! plan — float and streamlined-integer, batch-1 and batch-8, across the
//! compiler's option axes — must verify with **zero errors**. A failure
//! here means either the compiler emitted a plan that breaks one of its
//! own invariants, or the verifier grew a false positive; both are
//! ship-stoppers.
//!
//! (Positive results — each mutation class tripping its expected
//! diagnostic — live in the unit tests, `src/verify/tests.rs`.)

use qonnx::ir::ModelGraph;
use qonnx::plan::{ExecutionPlan, PlanOptions};
use qonnx::verify::verify_plan;
use qonnx::{transforms, zoo};

/// Option combinations that change what the verifier sees: generic
/// dispatch, unfused packed kernels, float-only tier, convert-per-call
/// residency, and the everything-on default.
fn option_axes() -> [PlanOptions; 5] {
    [
        PlanOptions::default(),
        PlanOptions { specialize: false, ..Default::default() },
        PlanOptions { fuse_epilogues: false, ..Default::default() },
        PlanOptions { quantize: false, ..Default::default() },
        PlanOptions { int_residency: false, ..Default::default() },
    ]
}

fn assert_verifies(g: &ModelGraph, label: &str) {
    for (i, opts) in option_axes().iter().enumerate() {
        let plan = ExecutionPlan::compile_with(g, opts)
            .unwrap_or_else(|e| panic!("{label} combo {i}: compile failed: {e:#}"));
        let report = verify_plan(&plan, g);
        assert!(!report.has_errors(), "{label} combo {i}:\n{}", report.render());
    }
}

#[test]
fn zoo_float_plans_verify_clean() {
    for name in ["TFC-w1a1", "TFC-w1a2", "TFC-w2a2", "CNV-w1a1", "CNV-w2a2"] {
        let mut g = zoo::build(name, 1, 32).unwrap();
        transforms::cleanup(&mut g).unwrap();
        assert_verifies(&g, name);
    }
}

#[test]
fn zoo_streamlined_plans_verify_clean() {
    for name in ["TFC-w1a1", "TFC-w2a2", "CNV-w2a2"] {
        let mut g = zoo::build(name, 1, 32).unwrap();
        transforms::cleanup(&mut g).unwrap();
        let sl = qonnx::streamline::try_streamline(&g).unwrap();
        assert!(sl.report.ok, "'{name}' must streamline:\n{}", sl.report.render());
        assert_verifies(&sl.graph, &format!("{name} (streamlined)"));
    }
}

#[test]
fn batch8_tfc_plans_verify_clean() {
    let params = zoo::TfcParams::random(2, 2, 1);
    let mut g = zoo::tfc_batch(&params, 8).unwrap();
    transforms::cleanup(&mut g).unwrap();
    assert_verifies(&g, "TFC-w2a2 (batch 8)");
}

#[test]
fn keraslike_plan_verifies_clean() {
    let mut g = zoo::keras_to_qonnx(&zoo::KerasModel::fig4_example(), 1).unwrap();
    transforms::cleanup(&mut g).unwrap();
    assert_verifies(&g, "keraslike fig4");
}
